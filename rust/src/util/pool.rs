//! Persistent sharded thread pool — the execution substrate of the
//! sparsification engine (EXPERIMENTS.md §Perf).
//!
//! Design goals, in order:
//!
//! 1. **Determinism.** Work is split into *indexed tasks*; which OS
//!    thread runs a task never affects results because every consumer
//!    writes only its own disjoint output (see [`SharedSlice`]) and
//!    merges happen in task order on the caller.  [`shard_range`] is
//!    the single source of truth for the shard -> index-range mapping.
//! 2. **Zero per-round setup.** Threads are spawned once and parked on
//!    a condvar between jobs — no `thread::spawn` in any hot path
//!    (the seed trainer spawned N threads per round).
//! 3. **std-only.** No crossbeam/rayon; one `Mutex<State>` + two
//!    condvars.  Work-stealing is deliberately absent: shards are
//!    claimed from a shared counter, which is enough because shard
//!    costs are uniform (contiguous equal ranges of the same kernel).
//!
//! The caller of [`ThreadPool::run`] participates in execution, so a
//! pool with `t` worker threads uses `t + 1` executors.  Nested `run`
//! calls (a pooled task itself calling `run`) execute inline serially
//! instead of deadlocking on the job slot.
//!
//! # Debug-build borrow auditing
//!
//! `SharedSlice` is the crate's one aliasing loophole: it hands out
//! `&mut [T]` from `&self`, and soundness rests on call-site shard
//! math keeping the ranges disjoint.  Under `cfg(debug_assertions)`
//! (or the `pool-audit` feature) every [`SharedSlice::range`] call is
//! checked by a dynamic borrow [`mod@audit`]or before the raw slice is
//! materialized: each slice registers its outstanding `(lo, hi)`
//! borrows per pool job, overlapping borrows from different tasks
//! panic with an `overlapping` diagnostic, and reusing a slice after
//! its job completed (or in a different job) panics with
//! `use-after-join`.  Borrows are released when the *job* ends, not
//! when the task ends, so an overlap between two tasks is detected on
//! every interleaving — the report is deterministic, not a lucky
//! race.  Release builds compile the auditor out entirely; the only
//! unconditional cost is one relaxed counter increment per job.
//!
//! Prefer the safe [`ThreadPool::for_shards`] / [`ThreadPool::map_mut`]
//! wrappers over raw `SharedSlice::range`: they encapsulate the
//! disjointness argument once, so call sites carry no `unsafe`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Deterministic contiguous shard -> range mapping: shard `s` of
/// `shards` over `len` elements covers `[s*len/shards, (s+1)*len/shards)`.
/// Ranges are disjoint, cover `0..len`, and differ in size by at most 1.
#[inline]
pub fn shard_range(len: usize, shards: usize, s: usize) -> (usize, usize) {
    debug_assert!(s < shards);
    (s * len / shards, (s + 1) * len / shards)
}

/// Monotone pool-job identity.  Unconditional (one relaxed increment
/// per job) so the borrow auditor can name jobs in its diagnostics
/// without changing the pool's shape between build profiles.
static NEXT_JOB: AtomicU64 = AtomicU64::new(1);

/// Dynamic borrow auditor for [`SharedSlice`] — compiled only into
/// debug builds (or with the `pool-audit` feature).  See the module
/// docs for the discipline it enforces.
#[cfg(any(debug_assertions, feature = "pool-audit"))]
mod audit {
    use std::cell::Cell;
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Mutex, MutexGuard};

    /// One outstanding `range()` borrow of a slice, attributed to the
    /// task index that took it.
    struct Borrow {
        lo: usize,
        hi: usize,
        task: usize,
    }

    /// Audit state for one `SharedSlice` instance (keyed by its epoch).
    #[derive(Default)]
    struct SliceState {
        /// the first pool job this slice was ranged in; `range()` from
        /// any other job — or outside any job once bound — panics
        job: Option<u64>,
        borrows: Vec<Borrow>,
    }

    static NEXT_EPOCH: AtomicU64 = AtomicU64::new(1);
    // BTreeMap, not HashMap: the analyzer's wall-clock rule bans
    // randomly-seeded hashers crate-wide, auditor included.
    static REGISTRY: Mutex<BTreeMap<u64, SliceState>> = Mutex::new(BTreeMap::new());

    /// Entry cap: `end_job` prunes job-less, borrow-less entries older
    /// than this window so long runs cannot grow the registry without
    /// bound.  Use-after-join detection is exact inside the window and
    /// best-effort (entry pruned -> slice looks fresh) beyond it.
    const MAX_ENTRIES: usize = 65_536;
    const EPOCH_WINDOW: u64 = 32_768;

    thread_local! {
        /// `(job, task)` while this thread executes a pooled task.
        static CUR: Cell<Option<(u64, usize)>> = const { Cell::new(None) };
    }

    fn registry() -> MutexGuard<'static, BTreeMap<u64, SliceState>> {
        // poison-tolerant: the auditor's own panics unwind while the
        // guard is live, and the map is never left mid-mutation
        REGISTRY.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn new_epoch() -> u64 {
        NEXT_EPOCH.fetch_add(1, Ordering::Relaxed)
    }

    /// Check one `range(lo, hi)` call *before* the raw slice is
    /// materialized (a panic here prevents the aliasing UB instead of
    /// reporting it after the fact, which keeps the Miri lane clean).
    pub fn on_range(epoch: u64, lo: usize, hi: usize, len: usize) {
        assert!(
            lo <= hi && hi <= len,
            "SharedSlice::range {lo}..{hi} out of bounds for len {len}"
        );
        if lo == hi {
            // zero-length views alias nothing; touching shard
            // boundaries ([a, m) / [m, b)) are likewise disjoint
            return;
        }
        let cur = CUR.with(Cell::get);
        let mut reg = registry();
        let entry = reg.entry(epoch).or_default();
        match (entry.job, cur) {
            (Some(bound), Some((job, task))) => {
                assert!(
                    bound == job,
                    "SharedSlice use-after-join: slice bound to pool job {bound} \
                     was ranged again in job {job}; create a fresh SharedSlice \
                     per pool job"
                );
                check_and_register(entry, lo, hi, task);
            }
            (Some(bound), None) => {
                panic!(
                    "SharedSlice use-after-join: slice bound to pool job {bound} \
                     was ranged after that job completed; the backing slice may \
                     no longer be exclusively borrowed"
                );
            }
            (None, Some((job, task))) => {
                entry.job = Some(job);
                check_and_register(entry, lo, hi, task);
            }
            // Serial use outside any pool job: the caller still holds
            // the exclusive `&mut` it built the slice from, so plain
            // sequential re-borrowing is sound and goes unregistered
            // (there is no job end to release at).
            (None, None) => {}
        }
    }

    fn check_and_register(entry: &mut SliceState, lo: usize, hi: usize, task: usize) {
        for b in &entry.borrows {
            // same-task borrows are sequential on one thread and are
            // allowed to overlap (re-deriving a view is not a race)
            assert!(
                b.task == task || lo >= b.hi || hi <= b.lo,
                "SharedSlice overlapping shard borrows: task {task} took \
                 {lo}..{hi} while task {} holds {}..{}; shard ranges handed \
                 to a pool job must be disjoint",
                b.task,
                b.lo,
                b.hi
            );
        }
        entry.borrows.push(Borrow { lo, hi, task });
    }

    /// Job teardown: release the job's borrows (its tasks have all
    /// completed) but keep the job binding, so a slice from this job
    /// ranged later still reports use-after-join.
    fn end_job(job: u64) {
        let mut reg = registry();
        for st in reg.values_mut() {
            if st.job == Some(job) {
                st.borrows.clear();
            }
        }
        if reg.len() > MAX_ENTRIES {
            let cutoff = NEXT_EPOCH.load(Ordering::Relaxed).saturating_sub(EPOCH_WINDOW);
            reg.retain(|&epoch, st| !st.borrows.is_empty() || epoch >= cutoff);
        }
    }

    /// RAII marker: this thread is executing task `task` of job `job`.
    /// Saves/restores the previous marker so nested inline jobs work.
    pub struct TaskScope {
        prev: Option<(u64, usize)>,
    }

    impl TaskScope {
        pub fn enter(job: u64, task: usize) -> Self {
            TaskScope { prev: CUR.with(|c| c.replace(Some((job, task)))) }
        }
    }

    impl Drop for TaskScope {
        fn drop(&mut self) {
            CUR.with(|c| c.set(self.prev));
        }
    }

    /// RAII job teardown — runs on unwind too, so a panicked job still
    /// releases its borrows.
    pub struct JobScope(pub u64);

    impl Drop for JobScope {
        fn drop(&mut self) {
            end_job(self.0);
        }
    }
}

/// Pointer-with-length wrapper that lets pooled tasks write **disjoint**
/// ranges of one slice in parallel.  The type is `Copy` so a `Fn`
/// closure can hand it to every shard.
///
/// Safety contract: concurrent [`Self::range`] calls must use
/// non-overlapping ranges, and the backing slice must outlive the pool
/// job — which [`ThreadPool::run`] guarantees by blocking until every
/// task is done.  Debug builds *enforce* the contract dynamically: see
/// the module docs on borrow auditing.
pub struct SharedSlice<T> {
    ptr: *mut T,
    len: usize,
    /// audit identity of this slice instance (debug builds only)
    #[cfg(any(debug_assertions, feature = "pool-audit"))]
    epoch: u64,
}

impl<T> Clone for SharedSlice<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SharedSlice<T> {}

// SAFETY: a SharedSlice is just `(ptr, len)` into a `&mut [T]` owned
// by the job issuer; moving it to another thread moves no T and the
// range() contract (disjoint ranges, slice outlives the job) is what
// permits the target thread to touch T — hence the `T: Send` bound.
unsafe impl<T: Send> Send for SharedSlice<T> {}
// SAFETY: `&SharedSlice` only exposes `range()`, whose contract makes
// concurrent use from many threads equivalent to `split_at_mut`
// hand-outs of one `&mut [T]`; `T: Send` is exactly the bound scoped
// thread spawns require for that.
unsafe impl<T: Send> Sync for SharedSlice<T> {}

impl<T> SharedSlice<T> {
    pub fn new(slice: &mut [T]) -> Self {
        SharedSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            #[cfg(any(debug_assertions, feature = "pool-audit"))]
            epoch: audit::new_epoch(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `[lo, hi)`.
    ///
    /// # Safety
    /// Concurrent callers must use disjoint ranges; the backing slice
    /// must be live for the duration of the borrow.  In debug builds
    /// the borrow auditor panics on violations before the view is
    /// created.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, lo: usize, hi: usize) -> &mut [T] {
        #[cfg(any(debug_assertions, feature = "pool-audit"))]
        audit::on_range(self.epoch, lo, hi, self.len);
        debug_assert!(lo <= hi && hi <= self.len, "range {lo}..{hi} of {}", self.len);
        // SAFETY: `[lo, hi)` is in bounds (caller contract, asserted
        // above in debug builds), `ptr` points at the live backing
        // slice for the duration of the job, and disjointness across
        // concurrent callers is the caller's contract (audited in
        // debug builds) — so this view aliases no other live `&mut`.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo) }
    }
}

/// Type-erased borrowed task: a raw pointer to the caller's closure
/// plus a monomorphized trampoline that knows its concrete type.  No
/// lifetime is laundered — the pointer is only dereferenced while the
/// issuing `run` call is blocked (a claim holds the job's `remaining`
/// count up, and the job owner cannot return while `remaining > 0`),
/// so the closure strictly outlives every call through `call`.
#[derive(Clone, Copy)]
struct RawTask {
    data: *const (),
    // SAFETY: contract of the fn pointer — see [`call_closure`]:
    // `data` must point at a live `F` when called.
    call: unsafe fn(*const (), usize),
}

// SAFETY: RawTask is a plain pointer pair; the pointee closure is
// `Sync` (enforced where the pointer is created, in `run`), so calling
// it from worker threads while the issuer keeps it alive is sound.
unsafe impl Send for RawTask {}

/// Trampoline stored in [`RawTask::call`].
///
/// # Safety
/// `data` must point to a live `F` — guaranteed by `run` blocking
/// until every claimed index completes.
unsafe fn call_closure<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    // SAFETY: `data` was created from `&F` in `run` and the issuer is
    // still blocked in `run`, so the reference is valid; `F: Sync`
    // permits calling it from this thread.
    let f = unsafe { &*data.cast::<F>() };
    f(i);
}

struct Job {
    task: RawTask,
    /// pool-wide job identity (audit diagnostics name jobs by this)
    id: u64,
    n: usize,
    next: usize,
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct State {
    job: Option<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
}

impl Shared {
    /// Poison-tolerant state lock: a panic that unwinds through `run`
    /// (task panics are re-raised there while the `run_lock` guard is
    /// live) must not brick the pool for subsequent jobs.
    fn lock(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// The persistent pool.  One global instance (see [`global`]) is shared
/// by the trainer's worker fan-out and every in-sparsifier kernel.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// serializes concurrent `run` calls (the pool runs one job at a time)
    run_lock: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

thread_local! {
    /// Set while this thread executes a pooled task; nested `run` calls
    /// detect it and execute inline (serially) instead of deadlocking.
    static IN_POOL_TASK: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

impl ThreadPool {
    /// Spawn a pool with `threads` worker threads.  `threads == 0` is
    /// valid: every job then runs inline on the caller.
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("regtopk-pool-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, run_lock: Mutex::new(()), handles }
    }

    /// Total executors a job can use (workers + the participating caller).
    pub fn parallelism(&self) -> usize {
        self.handles.len() + 1
    }

    /// Run `f(0), f(1), ..., f(tasks-1)` across the pool and block until
    /// all complete.  Which thread runs which index is unspecified;
    /// callers must make outputs index-deterministic (disjoint writes
    /// merged in index order).  Panics in any task are re-raised here
    /// after the whole job has drained.
    pub fn run<F: Fn(usize) + Sync>(&self, tasks: usize, f: F) {
        if tasks == 0 {
            return;
        }
        // inline paths: trivial job, no workers, or nested call from a
        // pooled task (running inline keeps progress + avoids deadlock)
        if tasks == 1 || self.handles.is_empty() || IN_POOL_TASK.with(|c| c.get()) {
            let _job_id = NEXT_JOB.fetch_add(1, Ordering::Relaxed);
            #[cfg(any(debug_assertions, feature = "pool-audit"))]
            let _audit_job = audit::JobScope(_job_id);
            for i in 0..tasks {
                #[cfg(any(debug_assertions, feature = "pool-audit"))]
                let _task = audit::TaskScope::enter(_job_id, i);
                f(i);
            }
            return;
        }
        let _serial = self.run_lock.lock().unwrap_or_else(|p| p.into_inner());
        let job_id = NEXT_JOB.fetch_add(1, Ordering::Relaxed);
        #[cfg(any(debug_assertions, feature = "pool-audit"))]
        let _audit_job = audit::JobScope(job_id);
        let task = RawTask {
            data: std::ptr::from_ref(&f).cast::<()>(),
            call: call_closure::<F>,
        };
        {
            let mut st = self.shared.lock();
            debug_assert!(st.job.is_none(), "run_lock must serialize jobs");
            st.job =
                Some(Job { task, id: job_id, n: tasks, next: 0, remaining: tasks, panic: None });
            self.shared.work_cv.notify_all();
        }
        // caller participates in execution
        drain_current_job(&self.shared);
        // wait for stragglers, then collect the finished job
        let job = {
            let mut st = self.shared.lock();
            while st.job.as_ref().map(|j| j.remaining > 0).unwrap_or(false) {
                st = self
                    .shared
                    .done_cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
            st.job.take().expect("job stays in the slot until its owner takes it")
        };
        if let Some(payload) = job.panic {
            std::panic::resume_unwind(payload);
        }
    }

    /// Run `f(i, &mut items[i])` for every item in parallel and return
    /// the per-item results in index order.  The disjoint `&mut`
    /// hand-out is what the seed's per-round `thread::scope` fan-out
    /// did with scoped spawns, minus the per-round thread creation.
    pub fn map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        {
            let items_sh = SharedSlice::new(items);
            let out_sh = SharedSlice::new(&mut out);
            self.run(n, |i| {
                // SAFETY: each index is claimed exactly once, so the
                // `[i, i+1)` item views are disjoint across tasks, and
                // `items` outlives this `run` call.
                let item = unsafe { &mut items_sh.range(i, i + 1)[0] };
                // SAFETY: same disjointness argument for the output
                // slot of index `i`; `out` outlives this `run` call.
                let slot = unsafe { &mut out_sh.range(i, i + 1)[0] };
                *slot = Some(f(i, item));
            });
        }
        out.into_iter()
            .map(|r| r.expect("pool job completed every index"))
            .collect()
    }

    /// Sharded parallel mutation of one slice with **no caller-side
    /// `unsafe`**: runs `f(s, lo, shard)` for every shard `s`, where
    /// `shard` is the exclusive view of `data[lo..hi)` given by
    /// [`shard_range`].  This wrapper owns the disjointness argument
    /// once, so kernels that only need "split this buffer across the
    /// pool" never touch [`SharedSlice`] directly.
    pub fn for_shards<T, F>(&self, data: &mut [T], shards: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        if shards == 0 {
            return;
        }
        let sh = SharedSlice::new(data);
        self.run(shards, |s| {
            let (lo, hi) = shard_range(sh.len(), shards, s);
            // SAFETY: shard_range partitions 0..len into disjoint
            // ranges, one per task index, and `run` blocks until every
            // task completes, so `data` outlives every view.
            let part = unsafe { sh.range(lo, hi) };
            f(s, lo, part);
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim-and-execute loop shared by pool workers and the participating
/// caller: repeatedly claim the next unclaimed index of the job in the
/// slot and run it; return when nothing is claimable.  The task pointer
/// is read under the same lock as the claim, so it always belongs to
/// the job the index was claimed from.
fn drain_current_job(shared: &Shared) {
    loop {
        let (i, task, _job_id) = {
            let mut st = shared.lock();
            match st.job.as_mut() {
                Some(job) if job.next < job.n => {
                    let i = job.next;
                    job.next += 1;
                    (i, job.task, job.id)
                }
                _ => return,
            }
        };
        IN_POOL_TASK.with(|c| c.set(true));
        #[cfg(any(debug_assertions, feature = "pool-audit"))]
        let task_scope = audit::TaskScope::enter(_job_id, i);
        let result = catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: our claim keeps `remaining > 0`, so the job owner
            // is still blocked in `run` and the closure behind
            // `task.data` is alive; `call` is the trampoline
            // monomorphized for its concrete type.
            unsafe { (task.call)(task.data, i) }
        }));
        #[cfg(any(debug_assertions, feature = "pool-audit"))]
        drop(task_scope);
        IN_POOL_TASK.with(|c| c.set(false));
        let mut st = shared.lock();
        let job = st.job.as_mut().expect("job lives until its owner takes it");
        job.remaining -= 1;
        if let Err(payload) = result {
            if job.panic.is_none() {
                job.panic = Some(payload);
            }
        }
        if job.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        // park until there is claimable work (or shutdown)
        {
            let mut st = shared.lock();
            loop {
                if st.shutdown {
                    return;
                }
                match st.job.as_ref() {
                    Some(job) if job.next < job.n => break,
                    _ => st = shared.work_cv.wait(st).unwrap_or_else(|p| p.into_inner()),
                }
            }
        }
        drain_current_job(shared);
    }
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide pool, sized to the machine (capped at 16 executors)
/// and created on first use.  Shared by the trainer fan-out and every
/// sparsifier engine so round-over-round there is exactly one set of
/// threads, all parked when idle.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        // caller participates, so spawn one fewer worker thread
        ThreadPool::new(n.clamp(1, 16) - 1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn shard_ranges_partition_exactly() {
        for &(len, shards) in &[(10usize, 3), (7, 7), (5, 8), (1_000_003, 16), (0, 4), (1, 1)] {
            let mut covered = 0usize;
            let mut prev_hi = 0usize;
            for s in 0..shards {
                let (lo, hi) = shard_range(len, shards, s);
                assert_eq!(lo, prev_hi, "len={len} shards={shards} s={s}");
                assert!(hi >= lo && hi <= len);
                covered += hi - lo;
                prev_hi = hi;
            }
            assert_eq!(covered, len);
            assert_eq!(prev_hi, len);
        }
    }

    #[test]
    fn run_executes_every_index_once() {
        let pool = ThreadPool::new(3);
        let counts: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        pool.run(257, |i| {
            counts[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn run_reusable_across_jobs() {
        let pool = ThreadPool::new(2);
        for round in 0..50 {
            let total = AtomicUsize::new(0);
            pool.run(8, |i| {
                total.fetch_add(i + round, Ordering::SeqCst);
            });
            assert_eq!(total.load(Ordering::SeqCst), 28 + 8 * round);
        }
    }

    #[test]
    fn map_mut_gives_disjoint_mut_access() {
        let pool = ThreadPool::new(3);
        let mut items: Vec<usize> = (0..64).collect();
        let doubled = pool.map_mut(&mut items, |i, v| {
            *v *= 2;
            *v + i
        });
        for i in 0..64 {
            assert_eq!(items[i], 2 * i);
            assert_eq!(doubled[i], 3 * i);
        }
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = ThreadPool::new(2);
        let total = AtomicUsize::new(0);
        pool.run(4, |_| {
            // nested call from inside a pooled task must not deadlock
            global().run(4, |j| {
                total.fetch_add(j + 1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * 10);
    }

    #[test]
    fn zero_workers_runs_inline() {
        let pool = ThreadPool::new(0);
        let total = AtomicUsize::new(0);
        pool.run(5, |i| {
            total.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn panics_propagate_after_drain() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // pool still usable after a panicked job
        let total = AtomicUsize::new(0);
        pool.run(4, |i| {
            total.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn shared_slice_disjoint_parallel_writes() {
        let pool = ThreadPool::new(3);
        // Miri executes this test too; a smaller buffer keeps the
        // interpreted run inside the lane's time budget.
        let n = if cfg!(miri) { 4_096 } else { 100_000 };
        let mut v = vec![0u64; n];
        {
            let sh = SharedSlice::new(&mut v);
            pool.run(8, |s| {
                let (lo, hi) = shard_range(sh.len(), 8, s);
                // SAFETY: shard_range yields disjoint ranges per task
                // index and `v` outlives the `run` call.
                let part = unsafe { sh.range(lo, hi) };
                for (off, x) in part.iter_mut().enumerate() {
                    *x = (lo + off) as u64;
                }
            });
        }
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
    }

    #[test]
    fn for_shards_covers_exactly_without_unsafe() {
        let pool = ThreadPool::new(3);
        let mut v = vec![0u32; 1_001];
        pool.for_shards(&mut v, 7, |_s, lo, part| {
            for (off, x) in part.iter_mut().enumerate() {
                *x = (lo + off) as u32 + 1;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32 + 1);
        }
    }
}
