//! Micro-benchmark harness used by every `cargo bench` target.
//!
//! criterion is unavailable offline, so this provides the subset we
//! need: warmup, timed batches, median + MAD + throughput reporting,
//! and a black_box.  Output format is one line per benchmark:
//!
//!   bench <name> ... median 12.34 us  (mad 0.56 us, n=64, 8.1 Melem/s)
//!
//! which the EXPERIMENTS.md tables are built from.

use std::time::{Duration, Instant};

/// Prevent the optimizer from eliding a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

/// Benchmark runner with a per-target time budget.
pub struct Bench {
    /// max wall-clock budget per benchmark
    pub budget: Duration,
    /// minimum sample count
    pub min_samples: usize,
    results: Vec<(String, f64)>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // Keep default budgets modest: the bench suite covers many
        // (sparsifier, J, k) points and must finish in minutes.
        Bench { budget: Duration::from_millis(700), min_samples: 10, results: Vec::new() }
    }

    pub fn with_budget(budget: Duration) -> Self {
        Bench { budget, ..Bench::new() }
    }

    /// Time `f`, which should perform ONE logical iteration per call.
    /// Returns the median seconds/iter and prints a summary line.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        // warmup: at least 3 calls or 10% of budget
        let warm_deadline = Instant::now() + self.budget / 10;
        for _ in 0..3 {
            f();
        }
        while Instant::now() < warm_deadline {
            f();
        }
        // sample
        let mut samples = Vec::new();
        let deadline = Instant::now() + self.budget;
        while samples.len() < self.min_samples || Instant::now() < deadline {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() >= 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mad = {
            let mut d: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
            d.sort_by(|a, b| a.partial_cmp(b).unwrap());
            d[d.len() / 2]
        };
        println!(
            "bench {name:<44} median {:>10}  (mad {}, n={})",
            fmt_time(median),
            fmt_time(mad),
            samples.len()
        );
        self.results.push((name.to_string(), median));
        median
    }

    /// Like `run` but also reports elements/second for `elems` per iter.
    pub fn run_throughput<F: FnMut()>(&mut self, name: &str, elems: usize, f: F) -> f64 {
        let median = self.run(name, f);
        if median > 0.0 {
            println!(
                "      {:<44} throughput {:.2} Melem/s",
                name,
                elems as f64 / median / 1e6
            );
        }
        median
    }

    /// All recorded (name, median_secs) pairs.
    pub fn results(&self) -> &[(String, f64)] {
        &self.results
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::with_budget(Duration::from_millis(50));
        let m = b.run("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(m > 0.0 && m < 0.1);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
