//! Micro-benchmark harness used by every `cargo bench` target.
//!
//! criterion is unavailable offline, so this provides the subset we
//! need: warmup, timed batches, median + MAD + throughput reporting,
//! and a black_box.  Output format is one line per benchmark:
//!
//!   bench <name> ... median 12.34 us  (mad 0.56 us, n=64, 8.1 Melem/s)
//!
//! which the EXPERIMENTS.md tables are built from.  In addition,
//! [`Bench::write_json`] emits machine-readable results (name ->
//! median s/iter + throughput) so the perf trajectory is trackable
//! across PRs — the bench targets merge into `BENCH_PR1.json` (or
//! `$BENCH_JSON`) at the repo root.
//!
//! Env knobs: `BENCH_BUDGET_MS` overrides the per-target time budget
//! (the `scripts/verify.sh` smoke run uses a small one).

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Prevent the optimizer from eliding a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

/// One recorded measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median_s: f64,
    /// elements per iteration (0 = throughput not reported)
    pub elems: usize,
}

/// Benchmark runner with a per-target time budget.
pub struct Bench {
    /// max wall-clock budget per benchmark
    pub budget: Duration,
    /// minimum sample count
    pub min_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // Keep default budgets modest: the bench suite covers many
        // (sparsifier, J, k) points and must finish in minutes.
        let ms = std::env::var("BENCH_BUDGET_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(700u64);
        Bench { budget: Duration::from_millis(ms), min_samples: 10, results: Vec::new() }
    }

    pub fn with_budget(budget: Duration) -> Self {
        Bench { budget, ..Bench::new() }
    }

    /// Time `f`, which should perform ONE logical iteration per call.
    /// Returns the median seconds/iter and prints a summary line.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> f64 {
        self.run_elems(name, 0, f)
    }

    /// Like `run` but also reports elements/second for `elems` per iter.
    pub fn run_throughput<F: FnMut()>(&mut self, name: &str, elems: usize, f: F) -> f64 {
        let median = self.run_elems(name, elems, f);
        if median > 0.0 && elems > 0 {
            println!(
                "      {:<44} throughput {:.2} Melem/s",
                name,
                elems as f64 / median / 1e6
            );
        }
        median
    }

    fn run_elems<F: FnMut()>(&mut self, name: &str, elems: usize, mut f: F) -> f64 {
        // warmup: at least 3 calls or 10% of budget
        let warm_deadline = Instant::now() + self.budget / 10;
        for _ in 0..3 {
            f();
        }
        while Instant::now() < warm_deadline {
            f();
        }
        // sample
        let mut samples = Vec::new();
        let deadline = Instant::now() + self.budget;
        while samples.len() < self.min_samples || Instant::now() < deadline {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() >= 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mad = {
            let mut d: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
            d.sort_by(|a, b| a.partial_cmp(b).unwrap());
            d[d.len() / 2]
        };
        println!(
            "bench {name:<44} median {:>10}  (mad {}, n={})",
            fmt_time(median),
            fmt_time(mad),
            samples.len()
        );
        self.results.push(BenchResult { name: name.to_string(), median_s: median, elems });
        median
    }

    /// All recorded results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Merge this run's results into a JSON file keyed by benchmark
    /// name: `{name: {"median_s": .., "melem_per_s": ..}}`.  Existing
    /// entries for other benchmarks are preserved, so several bench
    /// targets can share one trajectory file (BENCH_PR1.json).
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        let mut map: BTreeMap<String, Json> = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|j| j.as_obj().cloned())
            .unwrap_or_default();
        for r in &self.results {
            let mut entry = BTreeMap::new();
            entry.insert("median_s".to_string(), Json::Num(r.median_s));
            if r.elems > 0 && r.median_s > 0.0 {
                entry.insert(
                    "melem_per_s".to_string(),
                    Json::Num(r.elems as f64 / r.median_s / 1e6),
                );
            }
            map.insert(r.name.clone(), Json::Obj(entry));
        }
        std::fs::write(path, Json::Obj(map).dump())
    }

    /// Write to `$BENCH_JSON` (default `BENCH_PR1.json`) and print the
    /// destination — the standard epilogue of every bench target.
    pub fn write_json_default(&self) {
        let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_PR1.json".to_string());
        match self.write_json(Path::new(&path)) {
            Ok(()) => println!("# wrote {} results to {path}", self.results.len()),
            Err(e) => eprintln!("# could not write {path}: {e}"),
        }
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::with_budget(Duration::from_millis(50));
        let m = b.run("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(m > 0.0 && m < 0.1);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }

    #[test]
    fn json_results_merge_across_runs() {
        let dir = std::env::temp_dir().join("regtopk_bench_json_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("bench.json");
        let _ = std::fs::remove_file(&path);

        let mut a = Bench::with_budget(Duration::from_millis(10));
        a.run_throughput("alpha", 1000, || {
            black_box((0..50).sum::<u64>());
        });
        a.write_json(&path).unwrap();

        let mut b = Bench::with_budget(Duration::from_millis(10));
        b.run("beta", || {
            black_box((0..50).sum::<u64>());
        });
        b.write_json(&path).unwrap();

        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let obj = j.as_obj().unwrap();
        assert!(obj.contains_key("alpha"), "first run preserved");
        assert!(obj.contains_key("beta"));
        assert!(obj["alpha"].get("median_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(obj["alpha"].get("melem_per_s").and_then(Json::as_f64).unwrap() > 0.0);
        let _ = std::fs::remove_file(&path);
    }
}
