//! Minimal JSON: a recursive-descent parser + serializer.
//!
//! Used for `artifacts/manifest.json`, metrics dumps and experiment
//! configs.  Supports the full JSON grammar except `\u` surrogate
//! pairs outside the BMP (not needed by any producer in this repo —
//! still parsed, lone surrogates are replaced).

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch) ------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly (no whitespace).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder: `obj([("a", 1.0.into()), ...])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(items: I) -> Json {
    Json::Obj(
        items
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy the full UTF-8 code point
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar_values() {
        for s in ["null", "true", "false", "0", "-1.5", "3e4", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.dump()).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\"}", "tru", "1 2", "{\"a\":}"] {
            assert!(Json::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn roundtrip_escapes_and_unicode() {
        let v = Json::Str("q\"\\\n\tü€".to_string());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        assert_eq!(
            Json::parse(r#""ü""#).unwrap().as_str().unwrap(),
            "ü"
        );
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let m = r#"{"artifacts":{"linreg_grad":{"file":"linreg_grad.hlo.txt",
            "inputs":[{"shape":[100],"dtype":"f32"}],"outputs":2}}}"#;
        let v = Json::parse(m).unwrap();
        let a = v.get("artifacts").unwrap().get("linreg_grad").unwrap();
        assert_eq!(a.get("outputs").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            a.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape").unwrap().as_arr().unwrap()[0]
                .as_usize().unwrap(),
            100
        );
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" : [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
