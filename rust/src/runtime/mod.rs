//! The PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! Interchange is HLO *text* (see aot.py's docstring: jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects in proto
//! form; the text parser reassigns ids).  All artifacts were lowered
//! with `return_tuple=True`, so every execution returns one tuple
//! literal that is decomposed into `outputs` parts.
//!
//! The [`Runtime`] lazily compiles artifacts on first use and caches
//! the loaded executable — compilation happens once per process, never
//! in the per-round loop.

mod manifest;

pub use manifest::{ArtifactSpec, DType, InputSpec, Manifest, ModelInfo};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

/// Typed host-side tensor handed to [`Executable::call`].
#[derive(Clone, Debug)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::F32(data, shape.to_vec())
    }
    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::I32(data, shape.to_vec())
    }
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) => s,
        }
    }
    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32(..) => DType::F32,
            Tensor::I32(..) => DType::I32,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        Ok(match self {
            Tensor::F32(v, _) => xla::Literal::vec1(v).reshape(&dims)?,
            Tensor::I32(v, _) => xla::Literal::vec1(v).reshape(&dims)?,
        })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub name: String,
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: an Executable owns its PJRT handle exclusively; moving that
// ownership to another thread is sound because the PJRT C API imposes
// no thread affinity on loaded executables (the TfrtCpuClient used
// here is itself multi-threaded).  The `xla` crate simply does not
// annotate its raw-pointer wrappers.
unsafe impl Send for Executable {}
// SAFETY: the PJRT C API guarantees `PJRT_LoadedExecutable_Execute`
// and friends are thread-safe (the underlying client serializes/locks
// as needed; see the PJRT C API header contract), and our wrapper
// never exposes interior mutation through `&self`.
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with host tensors; validates shapes/dtypes against the
    /// manifest and returns `spec.outputs` f32 vectors (i32 outputs are
    /// not produced by any artifact in this repo).
    pub fn call(&self, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, want)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.shape() != want.shape.as_slice() || t.dtype() != want.dtype {
                bail!(
                    "{} input {i}: got {:?}{:?}, want {:?}{:?}",
                    self.name,
                    t.dtype(),
                    t.shape(),
                    want.dtype,
                    want.shape
                );
            }
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(Tensor::to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.spec.outputs,
                parts.len()
            );
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}

/// Artifact registry + PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: BTreeMap<String, Arc<Executable>>,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`, creates the
    /// PJRT CPU client).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, cache: BTreeMap::new() })
    }

    /// Default artifact dir: $REGTOPK_ARTIFACTS or ./artifacts.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("REGTOPK_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return a shareable handle to the artifact.
    pub fn load(&mut self, name: &str) -> Result<Arc<Executable>> {
        if !self.cache.contains_key(name) {
            let spec = self
                .manifest
                .artifacts
                .get(name)
                .with_context(|| format!("artifact '{name}' not in manifest"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(
                name.to_string(),
                Arc::new(Executable { name: name.to_string(), spec, exe }),
            );
        }
        Ok(self.cache[name].clone())
    }

    /// Initial flat parameter vector for a model (raw LE f32 file).
    pub fn load_init(&self, model: &str) -> Result<Vec<f32>> {
        let info = self
            .manifest
            .models
            .get(model)
            .with_context(|| format!("model '{model}' not in manifest"))?;
        let raw = std::fs::read(self.dir.join(&info.init_file))?;
        if raw.len() != 4 * info.param_count {
            bail!(
                "init file size {} != 4 * {}",
                raw.len(),
                info.param_count
            );
        }
        Ok(raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        let t = Tensor::f32(vec![1.0; 6], &[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic]
    fn tensor_rejects_bad_shape() {
        Tensor::f32(vec![1.0; 5], &[2, 3]);
    }

    #[test]
    fn open_missing_dir_errors() {
        assert!(Runtime::open("/nonexistent/artifacts").is_err());
    }
}
