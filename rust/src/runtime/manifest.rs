//! Typed view of `artifacts/manifest.json` (produced by aot.py).

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::grad::{FlatLayout, LayerSlice};
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: usize,
    pub doc: String,
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub param_count: usize,
    pub init_file: String,
    pub init_seed: u64,
    pub layout: FlatLayout,
}

impl ModelInfo {
    /// The model's per-layer parameter-group layout for the layer-wise
    /// sparsification path (`repro fig3 --layerwise`).  Errors when the
    /// manifest's layers are not a contiguous cover of the parameter
    /// vector — such a manifest cannot drive the bucketed wire format.
    pub fn grad_layout(&self) -> Result<crate::grad::GradLayout> {
        crate::grad::GradLayout::from_flat(&self.layout).map_err(|e| anyhow!("{e}"))
    }
}

/// The artifact registry.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut m = Manifest::default();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        for (name, a) in arts {
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(|i| {
                    let shape = i
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("{name}: bad shape"))?
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("{name}: bad dim")))
                        .collect::<Result<Vec<_>>>()?;
                    let dtype = match i.get("dtype").and_then(Json::as_str) {
                        Some("f32") => DType::F32,
                        Some("i32") => DType::I32,
                        other => return Err(anyhow!("{name}: bad dtype {other:?}")),
                    };
                    Ok(InputSpec { shape, dtype })
                })
                .collect::<Result<Vec<_>>>()?;
            m.artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: missing file"))?
                        .to_string(),
                    inputs,
                    outputs: a
                        .get("outputs")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("{name}: missing outputs"))?,
                    doc: a
                        .get("doc")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                },
            );
        }
        if let Some(models) = j.get("models").and_then(Json::as_obj) {
            for (name, mm) in models {
                let layers = mm
                    .get("layers")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(|l| {
                        Ok(LayerSlice {
                            name: l
                                .get("name")
                                .and_then(Json::as_str)
                                .ok_or_else(|| anyhow!("{name}: layer name"))?
                                .to_string(),
                            offset: l
                                .get("offset")
                                .and_then(Json::as_usize)
                                .ok_or_else(|| anyhow!("{name}: layer offset"))?,
                            size: l
                                .get("size")
                                .and_then(Json::as_usize)
                                .ok_or_else(|| anyhow!("{name}: layer size"))?,
                            shape: l
                                .get("shape")
                                .and_then(Json::as_arr)
                                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                                .unwrap_or_default(),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                let param_count = mm
                    .get("param_count")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{name}: param_count"))?;
                m.models.insert(
                    name.clone(),
                    ModelInfo {
                        param_count,
                        init_file: mm
                            .get("init_file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("{name}: init_file"))?
                            .to_string(),
                        init_seed: mm
                            .get("init_seed")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0) as u64,
                        layout: FlatLayout { layers, total: param_count },
                    },
                );
            }
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "linreg_grad": {
          "file": "linreg_grad.hlo.txt",
          "doc": "d",
          "inputs": [
            {"shape": [100], "dtype": "f32"},
            {"shape": [500, 100], "dtype": "f32"},
            {"shape": [500], "dtype": "f32"}
          ],
          "outputs": 2
        }
      },
      "models": {
        "mlp": {
          "param_count": 10,
          "init_file": "init_mlp.f32",
          "init_seed": 7,
          "layers": [
            {"name": "fc0.w", "shape": [2, 4], "offset": 0, "size": 8},
            {"name": "fc0.b", "shape": [2], "offset": 8, "size": 2}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_artifacts_and_models() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["linreg_grad"];
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].shape, vec![500, 100]);
        assert_eq!(a.outputs, 2);
        let mm = &m.models["mlp"];
        assert_eq!(mm.param_count, 10);
        assert_eq!(mm.layout.layers.len(), 2);
        assert_eq!(mm.layout.layers[1].offset, 8);
    }

    #[test]
    fn model_grad_layout_adopts_layers() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let layout = m.models["mlp"].grad_layout().unwrap();
        assert_eq!(layout.num_groups(), 2);
        assert_eq!(layout.total(), 10);
        assert_eq!(layout.group(1).name, "fc0.b");
        assert_eq!(layout.group(1).offset, 8);
        // a gapped manifest layout is a hard error, not a debug_assert
        let mut bad = m.models["mlp"].clone();
        bad.layout.layers[1].offset = 9;
        assert!(bad.grad_layout().is_err());
    }

    #[test]
    fn rejects_missing_sections() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": {"x": {"file": "f"}}}"#).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let p = std::path::Path::new("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(p).unwrap();
            assert!(m.artifacts.contains_key("regtopk_score"));
            assert!(m.models.contains_key("resnet8"));
        }
    }
}
