//! Metrics sink: per-iteration records, CSV/JSON writers, run manifests.
//!
//! Every experiment harness (examples/, `repro` subcommands, benches)
//! logs through a [`RunLog`]; EXPERIMENTS.md tables are generated from
//! the CSV/JSON these produce.  Records are append-only and the writer
//! is deterministic (BTreeMap-backed JSON), so identical runs produce
//! byte-identical outputs (DESIGN.md invariant 6).

#![forbid(unsafe_code)]

use std::io::Write;
use std::path::Path;

use crate::util::json::{obj, Json};

/// One training-iteration record.  Unused fields stay NaN/0 and are
/// omitted from sparse outputs.
#[derive(Clone, Copy, Debug)]
pub struct IterRecord {
    pub iter: usize,
    /// mean training loss across workers
    pub loss: f32,
    /// ||w - w*|| when the optimum is known (Fig. 2), else NaN
    pub opt_gap: f32,
    /// validation accuracy in [0,1] when evaluated, else NaN
    pub accuracy: f32,
    /// upload bytes this round (all workers)
    pub upload_bytes: usize,
    /// simulated comm time this round
    pub sim_time_s: f64,
    /// wall-clock compute time this round
    pub wall_time_s: f64,
}

impl IterRecord {
    pub fn new(iter: usize) -> Self {
        IterRecord {
            iter,
            loss: f32::NAN,
            opt_gap: f32::NAN,
            accuracy: f32::NAN,
            upload_bytes: 0,
            sim_time_s: 0.0,
            wall_time_s: 0.0,
        }
    }
}

/// A named run: config echo + records.
pub struct RunLog {
    pub name: String,
    pub config: Json,
    records: Vec<IterRecord>,
}

impl RunLog {
    pub fn new(name: impl Into<String>, config: Json) -> Self {
        RunLog { name: name.into(), config, records: Vec::new() }
    }

    pub fn push(&mut self, r: IterRecord) {
        self.records.push(r);
    }

    pub fn records(&self) -> &[IterRecord] {
        &self.records
    }

    pub fn last(&self) -> Option<&IterRecord> {
        self.records.last()
    }

    /// CSV with a fixed header; NaN fields serialize as empty cells.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("iter,loss,opt_gap,accuracy,upload_bytes,sim_time_s,wall_time_s\n");
        for r in &self.records {
            let f = |v: f32| if v.is_nan() { String::new() } else { format!("{v}") };
            out.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                r.iter,
                f(r.loss),
                f(r.opt_gap),
                f(r.accuracy),
                r.upload_bytes,
                r.sim_time_s,
                r.wall_time_s
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("name", Json::from(self.name.clone())),
            ("config", self.config.clone()),
            (
                "records",
                Json::Arr(
                    self.records
                        .iter()
                        .map(|r| {
                            let mut o = vec![("iter", Json::from(r.iter))];
                            if !r.loss.is_nan() {
                                o.push(("loss", Json::from(r.loss as f64)));
                            }
                            if !r.opt_gap.is_nan() {
                                o.push(("opt_gap", Json::from(r.opt_gap as f64)));
                            }
                            if !r.accuracy.is_nan() {
                                o.push(("accuracy", Json::from(r.accuracy as f64)));
                            }
                            o.push(("upload_bytes", Json::from(r.upload_bytes)));
                            obj(o)
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json().dump().as_bytes())
    }

    /// Terminal-friendly sparkline of a field (for example binaries).
    pub fn sparkline(&self, field: impl Fn(&IterRecord) -> f32, width: usize) -> String {
        let vals: Vec<f32> = self
            .records
            .iter()
            .map(&field)
            .filter(|v| v.is_finite())
            .collect();
        if vals.is_empty() {
            return String::new();
        }
        let chars = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let (lo, hi) = vals
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
        let span = (hi - lo).max(1e-12);
        let stride = (vals.len() as f64 / width as f64).max(1.0);
        let mut out = String::new();
        let mut i = 0.0;
        while (i as usize) < vals.len() && out.chars().count() < width {
            let v = vals[i as usize];
            let b = (((v - lo) / span) * 7.0).round() as usize;
            out.push(chars[b.min(7)]);
            i += stride;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunLog {
        let mut l = RunLog::new("t", obj([("k", Json::from(3usize))]));
        let mut r = IterRecord::new(0);
        r.loss = 1.5;
        r.upload_bytes = 10;
        l.push(r);
        let mut r = IterRecord::new(1);
        r.loss = 0.5;
        r.opt_gap = 0.1;
        l.push(r);
        l
    }

    #[test]
    fn csv_has_header_and_blank_nans() {
        let csv = sample().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("iter,loss"));
        assert!(lines[1].starts_with("0,1.5,,")); // opt_gap NaN -> empty
        assert!(lines[2].contains("0.1"));
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let j = sample().to_json();
        let re = Json::parse(&j.dump()).unwrap();
        assert_eq!(re.get("name").unwrap().as_str().unwrap(), "t");
        assert_eq!(re.get("records").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn writers_create_parent_dirs() {
        let dir = std::env::temp_dir().join(format!("regtopk_test_{}", std::process::id()));
        let path = dir.join("sub/run.csv");
        sample().write_csv(&path).unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sparkline_monotone_loss() {
        let mut l = RunLog::new("s", Json::Null);
        for i in 0..32 {
            let mut r = IterRecord::new(i);
            r.loss = 32.0 - i as f32;
            l.push(r);
        }
        let sl = l.sparkline(|r| r.loss, 8);
        assert_eq!(sl.chars().count(), 8);
        assert!(sl.starts_with('█'));
        // strictly decreasing series -> non-increasing block levels,
        // and the tail must sit well below the head
        let blocks = "▁▂▃▄▅▆▇█";
        let levels: Vec<usize> =
            sl.chars().map(|c| blocks.chars().position(|b| b == c).unwrap()).collect();
        assert!(levels.windows(2).all(|w| w[1] <= w[0]), "{levels:?}");
        assert!(*levels.last().unwrap() <= 2, "{levels:?}");
    }
}
