//! Networked-transport gates (PR 9 tentpole): a loopback-TCP star —
//! every message crossing a real socket as length-framed bytes —
//! replays the exact trajectory of the deterministic and threaded
//! drivers for every sparsifier family, flat and grouped, quantized
//! and downlink-compressed; the frame codec round-trips at boundary
//! sizes 0/1/tiny/large; and torn or corrupt frames fail with an
//! `Err`, never a panic or a wrong message.
//!
//! `GlobalTopK` is exercised by the deterministic driver only (see
//! `rust/tests/determinism.rs`): the genie needs a global
//! side-channel no message-passing transport provides.

use regtopk::comm::codec::{
    decode_header, decode_hello, decode_msg, decode_payload, encode_hello, encode_msg,
    FrameHeader, FrameKind, FRAME_HEADER_BYTES, HELLO_BYTES, HELLO_MAGIC,
};
use regtopk::comm::{kind_of, InProc, Msg, SparseUpdate, Transport, WorkerLink};
use regtopk::config::TrainConfig;
use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::fig2;
use regtopk::grad::GradLayout;
use regtopk::sparse::SparseVec;
use regtopk::sparsify::{BudgetPolicy, PolicyTable, SparsifierKind};
use regtopk::util::check;

/// Every non-genie family (the transports carry no global
/// side-channel, so `GlobalTopK` stays on the deterministic driver).
fn transport_families(dim: usize) -> Vec<SparsifierKind> {
    let k = (dim / 4).max(1);
    vec![
        SparsifierKind::Dense,
        SparsifierKind::TopK { k },
        SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
        SparsifierKind::RandK { k, seed: 5 },
        SparsifierKind::Threshold { tau: 0.5 },
        SparsifierKind::Dgc { k, momentum: 0.9, clip: 0.0 },
        SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 2 * k },
    ]
}

fn run_three_ways(cfg: &TrainConfig, seed: u64, iters: usize, label: &str) {
    let params = LinearParams {
        workers: cfg.workers,
        rows_per_worker: 40,
        dim: 16,
        ..LinearParams::fig2()
    };
    let problem = generate(params, seed);
    let mut det = fig2::trainer_from_config(cfg, &problem);
    let mut thr = fig2::trainer_from_config(cfg, &problem);
    let mut tcp = fig2::trainer_from_config(cfg, &problem);
    for _ in 0..iters {
        det.round();
    }
    thr.run_threaded(iters);
    let log = tcp.run_tcp_loopback(iters);
    assert_eq!(det.server.w, thr.server.w, "{label}: threaded trajectory diverged");
    assert_eq!(det.server.w, tcp.server.w, "{label}: tcp trajectory diverged");
    assert_eq!(log.records().len(), iters, "{label}");
    // the framed bytes charge exactly what the deterministic ledger
    // charged, both directions (run_transport additionally asserts
    // the socket counters equal these figures per round)
    assert_eq!(
        det.ledger.total_upload_bytes(),
        tcp.ledger.total_upload_bytes(),
        "{label}: uplink bytes"
    );
    assert_eq!(
        det.ledger.total_download_bytes(),
        tcp.ledger.total_download_bytes(),
        "{label}: downlink bytes"
    );
    assert_eq!(tcp.workers.len(), cfg.workers, "{label}: workers reclaimed");
}

/// Flat layout, every family: deterministic == threaded == TCP, in
/// trajectory and in ledger bytes.
#[test]
fn tcp_loopback_is_bit_identical_for_all_families_flat() {
    for kind in transport_families(16) {
        let cfg = TrainConfig {
            workers: 3,
            eta: 0.03,
            sparsifier: kind.clone(),
            eval_every: 0,
            ..TrainConfig::default()
        };
        run_three_ways(&cfg, 11, 8, &format!("{kind:?} flat"));
    }
}

/// Grouped layout with a global budget, every family.
#[test]
fn tcp_loopback_is_bit_identical_for_all_families_grouped() {
    let layout =
        GradLayout::from_sizes([("conv.w".to_string(), 12), ("conv.b".to_string(), 4)]);
    for kind in transport_families(16) {
        let cfg = TrainConfig {
            workers: 3,
            eta: 0.03,
            sparsifier: kind.clone(),
            eval_every: 0,
            groups: Some(layout.clone()),
            budget: Some(BudgetPolicy::Global { k: 4 }),
            ..TrainConfig::default()
        };
        run_three_ways(&cfg, 13, 8, &format!("{kind:?} grouped"));
    }
}

/// Quantized uplink (4-bit packed values) and Rice-coded indices:
/// codec payloads survive the socket framing bit-exactly.
#[test]
fn tcp_loopback_is_bit_identical_with_uplink_codecs() {
    let layout =
        GradLayout::from_sizes([("conv.w".to_string(), 12), ("conv.b".to_string(), 4)]);
    for spec in [
        "*=:bits=4",
        "*=:idx=rice",
        "*=:bits=4,idx=rice",
        // half-width wire values (PR 10): true 16-bit words, scale-free
        "*=:levels=fp16",
        "*=:levels=bf16,idx=rice",
    ] {
        let cfg = TrainConfig {
            workers: 3,
            eta: 0.03,
            sparsifier: SparsifierKind::RegTopK { k: 4, mu: 0.5, q: 1.0 },
            eval_every: 0,
            groups: Some(layout.clone()),
            budget: Some(BudgetPolicy::Global { k: 4 }),
            policy: Some(PolicyTable::parse(spec).unwrap()),
            ..TrainConfig::default()
        };
        run_three_ways(&cfg, 17, 8, spec);
    }
}

/// Downlink-compressed broadcasts (lossless sparse and 8-bit coded):
/// the `SparseBroadcast` frames replay the exact threaded protocol.
#[test]
fn tcp_loopback_is_bit_identical_with_downlink() {
    for spec in ["*=", "*=:bits=8,idx=rice"] {
        let cfg = TrainConfig {
            workers: 3,
            eta: 0.03,
            sparsifier: SparsifierKind::RegTopK { k: 4, mu: 0.5, q: 1.0 },
            eval_every: 0,
            downlink: Some(PolicyTable::parse(spec).unwrap()),
            ..TrainConfig::default()
        };
        run_three_ways(&cfg, 19, 8, &format!("downlink {spec}"));
    }
}

/// Frame round-trip property at boundary sizes 0/1/tiny/large, for
/// all three message kinds: decode(encode(m)) == m, stats agree, and
/// re-encoding is byte-identical.
#[test]
fn frames_roundtrip_at_boundary_sizes() {
    check::forall("frame_roundtrip_sizes", |rng, case| {
        let n = [0usize, 1, 1 + rng.below(7), 50 + rng.below(150)][case % 4];
        let dim = (n.max(1) * (1 + rng.below(500))).max(2);
        let mut idx = rng.sample_indices(dim, n);
        idx.sort_unstable();
        let idx: Vec<u32> = idx.into_iter().map(|i| i as u32).collect();
        let vals: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let up = SparseUpdate::single(SparseVec::new(dim, idx, vals));
        let msgs = [
            Msg::Update { worker: rng.below(8), round: case, update: up.clone(), loss: 0.25 },
            Msg::Broadcast { round: case, gagg: (0..2 * n).map(|i| i as f32).collect() },
            Msg::SparseBroadcast { round: case, w: vec![0.5; dim], gagg: up },
        ];
        for msg in msgs {
            let (bytes, st) = encode_msg(&msg);
            assert_eq!(
                kind_of(&msg),
                decode_header(&bytes[..FRAME_HEADER_BYTES]).expect("header").kind
            );
            let (back, st2) = decode_msg(&bytes).expect("decode");
            assert_eq!(back, msg, "n={n} dim={dim}");
            assert_eq!(st, st2);
            assert_eq!(encode_msg(&back).0, bytes, "re-encode byte-identity");
        }
    });
}

/// Torn and corrupt frames are decode errors, never panics: every
/// strict payload prefix fails, as do trailing bytes and a corrupt
/// header, while the intact frame still decodes.
#[test]
fn torn_and_corrupt_frames_error_cleanly() {
    let mut sv = SparseVec::zeros(32);
    sv.push(2, 1.5);
    sv.push(21, -0.75);
    let gagg = SparseUpdate::single(sv);
    let msg = Msg::SparseBroadcast { round: 6, w: vec![1.0; 32], gagg };
    let (bytes, _) = encode_msg(&msg);
    let h: FrameHeader = decode_header(&bytes[..FRAME_HEADER_BYTES]).expect("header");
    assert_eq!(h.kind, FrameKind::SparseBroadcast);
    for cut in 0..bytes.len() - FRAME_HEADER_BYTES {
        let torn = decode_payload(&h, &bytes[FRAME_HEADER_BYTES..FRAME_HEADER_BYTES + cut]);
        assert!(torn.is_err(), "payload cut at {cut} decoded");
    }
    let mut trailing = bytes.clone();
    trailing.push(0);
    assert!(decode_msg(&trailing).is_err(), "trailing byte accepted");
    for at in [0usize, 4, 6, 7] {
        let mut bad = bytes.clone();
        bad[at] ^= 0x40;
        assert!(decode_msg(&bad).is_err(), "corrupt header byte {at} accepted");
    }
    assert!(decode_msg(&bytes).is_ok(), "the intact frame still decodes");
}

/// PR 10 byte-shipping pin: the threaded star's channels carry
/// encoded frame bytes, so a message crossing `InProc` is the SAME
/// encode→decode round trip the socket backends perform — delivered
/// messages are bit-identical, and the star's counters account the
/// exact frame/wire bytes of each crossing, like `Tcp`'s.
#[test]
fn inproc_star_ships_frame_bytes_bit_identically() {
    let mut t = InProc::star(2);
    let mut links: Vec<_> = (0..2).map(|i| t.link(i)).collect();

    let down = Msg::Broadcast { round: 0, gagg: vec![1.0, -0.0, f32::MIN_POSITIVE / 4.0] };
    let (_, dst) = encode_msg(&down);
    t.broadcast(&down);
    for (i, l) in links.iter_mut().enumerate() {
        let got = l.recv().unwrap_or_else(|| panic!("worker {i} starved"));
        assert_eq!(got, down, "worker {i}: decoded broadcast diverged");
        assert_eq!(encode_msg(&got).0, encode_msg(&down).0, "worker {i}: byte identity");
    }

    let mut wire_up = 0usize;
    for (i, l) in links.iter_mut().enumerate() {
        let mut sv = SparseVec::zeros(64);
        sv.push(3 * i as u32 + 1, 0.5 - i as f32);
        let up = Msg::Update {
            worker: i,
            round: 1,
            update: SparseUpdate::single(sv),
            loss: 0.25,
        };
        wire_up += encode_msg(&up).1.wire;
        l.send(&up);
    }
    let got = t.gather_round(2, 1);
    assert_eq!(got.len(), 2);
    for (i, m) in got.iter().enumerate() {
        match m {
            Msg::Update { worker, round, .. } => assert_eq!((*worker, *round), (i, 1)),
            other => panic!("non-update gathered: {other:?}"),
        }
    }

    let c = t.counters().expect("byte-shipping InProc counts like a socket");
    assert_eq!(c.sent_frames, 2, "one broadcast frame per worker");
    assert_eq!(c.recv_frames, 2, "one update frame per worker");
    assert_eq!(c.sent_wire, 2 * dst.wire as u64, "downlink charged bytes");
    assert_eq!(c.recv_wire, wire_up as u64, "uplink charged bytes");
    assert!(c.sent_bytes > c.sent_wire, "frame headers are real but uncharged traffic");

    t.reset_counters();
    assert_eq!(t.counters(), Some(Default::default()), "reset zeroes the span");
}

/// The connection handshake round-trips and rejects corruption.
#[test]
fn hello_handshake_roundtrips() {
    let hello = encode_hello(42);
    assert_eq!(hello.len(), HELLO_BYTES);
    assert_eq!(&hello[..4], HELLO_MAGIC);
    assert_eq!(decode_hello(&hello), Ok(42));
    let mut bad = hello;
    bad[0] ^= 1;
    assert!(decode_hello(&bad).is_err(), "bad magic accepted");
    let mut wrong_version = hello;
    wrong_version[4] ^= 0xFF;
    assert!(decode_hello(&wrong_version).is_err(), "foreign version accepted");
    assert!(decode_hello(&hello[..9]).is_err(), "short handshake accepted");
}
