//! Contract of the layer-wise gradient API (PR 2 tentpole):
//!
//! 1. the degenerate single-group `GradLayout` is bit-identical to the
//!    seed/PR-1 flat path for ALL EIGHT sparsifier families — at the
//!    sparsifier level (trajectories) and through the full trainer
//!    (model, losses, upload accounting);
//! 2. the flat `step_into` compatibility path of a multi-group
//!    `LayerwiseSparsifier` equals its bucketed path flattened
//!    (property-tested over random layouts);
//! 3. checkpoints round-trip the `GradLayout`/`BudgetPolicy` through
//!    the config echo;
//! 4. a multi-group RegTop-k run with `Proportional` budgets completes
//!    end-to-end with per-group bytes in the ledger, and the threaded
//!    driver matches the deterministic one under groups.

use regtopk::config::TrainConfig;
use regtopk::coordinator::Checkpoint;
use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::fig2;
use regtopk::grad::{GradLayout, GradView};
use regtopk::comm::SparseUpdate;
use regtopk::sparsify::{
    build, BudgetPolicy, LayerwiseSparsifier, PolicyTable, RoundCtx, Sparsifier,
    SparsifierKind,
};
use regtopk::util::check;
use regtopk::util::rng::Rng;

/// Every family in the framework at a budget valid for `dim`.
fn all_kinds(dim: usize) -> Vec<SparsifierKind> {
    let k = (dim / 4).max(1);
    vec![
        SparsifierKind::Dense,
        SparsifierKind::TopK { k },
        SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
        SparsifierKind::RandK { k, seed: 5 },
        SparsifierKind::Threshold { tau: 0.5 },
        SparsifierKind::GlobalTopK { k },
        SparsifierKind::Dgc { k, momentum: 0.9, clip: 0.0 },
        SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 2 * k },
    ]
}

/// Sparsifier-level equivalence: the config-built single-group
/// layerwise stack reproduces the flat factory's trajectory bit for
/// bit, family by family (including the genie-aided gtopk).
#[test]
fn single_group_layout_bit_matches_flat_for_all_families() {
    let dim = 40;
    let layout = GradLayout::single(dim);
    for kind in all_kinds(dim) {
        let mut flat = build(&kind, dim, 0);
        let mut cfg = TrainConfig::default();
        cfg.sparsifier = kind.clone();
        cfg.groups = Some(layout.clone());
        let mut grouped = cfg.build_sparsifier(dim, 0);
        assert_eq!(grouped.name(), "layerwise");
        assert_eq!(grouped.needs_genie(), flat.needs_genie(), "{kind:?}");
        let mut rng = Rng::seed_from(9);
        let mut gagg = vec![0.0f32; dim];
        let mut up = SparseUpdate::empty();
        for t in 0..8 {
            let g = rng.gaussian_vec(dim, 1.0);
            // both sides see the same genie channel (gtopk only)
            let genie: Option<Vec<f32>> =
                if flat.needs_genie() { Some(flat.peek_acc(&g)) } else { None };
            let ctx = RoundCtx {
                t,
                gagg_prev: &gagg,
                omega: 0.25,
                genie_acc: genie.as_deref(),
            };
            // peek parity feeds the trainer's genie construction
            assert_eq!(flat.peek_acc(&g), grouped.peek_acc(&g), "{kind:?} t={t}");
            let want = flat.step(&g, &ctx);
            let view = GradView::new(&layout, &g);
            grouped.step_group_into(&view, &ctx, &mut up);
            assert_eq!(up.num_buckets(), 1, "{kind:?}");
            assert_eq!(want, up.flatten(), "{kind:?} t={t}");
            gagg = want.to_dense();
        }
    }
}

/// End-to-end equivalence: a full trainer run under the single-group
/// layout matches the flat config bitwise — model, per-round upload
/// bytes, totals — for every family.
#[test]
fn trainer_single_group_bit_matches_flat_for_all_families() {
    let params =
        LinearParams { workers: 4, rows_per_worker: 60, dim: 24, ..LinearParams::fig2() };
    let problem = generate(params, 7);
    for kind in all_kinds(24) {
        let flat_cfg = TrainConfig {
            workers: 4,
            eta: 0.03,
            sparsifier: kind.clone(),
            eval_every: 0,
            ..TrainConfig::default()
        };
        let mut grouped_cfg = flat_cfg.clone();
        grouped_cfg.groups = Some(GradLayout::single(24));
        let mut tr_flat = fig2::trainer_from_config(&flat_cfg, &problem);
        let mut tr_grp = fig2::trainer_from_config(&grouped_cfg, &problem);
        for _ in 0..25 {
            tr_flat.round();
            tr_grp.round();
        }
        assert_eq!(tr_flat.server.w, tr_grp.server.w, "{kind:?}");
        assert_eq!(
            tr_flat.ledger.total_upload_bytes(),
            tr_grp.ledger.total_upload_bytes(),
            "{kind:?}"
        );
        for (a, b) in tr_flat.ledger.rounds().iter().zip(tr_grp.ledger.rounds()) {
            assert_eq!(a.upload_bytes, b.upload_bytes, "{kind:?} round {}", a.round);
            assert_eq!(a.upload_entries, b.upload_entries, "{kind:?} round {}", a.round);
        }
    }
}

/// PR 3 equivalence extension: for EVERY family, a multi-group
/// homogeneous stack is bit-identical whether built by `new`, by
/// `with_policies` with an empty table, or by `with_policies` with a
/// table whose globs match no group — the heterogeneous machinery must
/// be invisible until a rule actually fires.
#[test]
fn homogeneous_multi_group_policy_table_is_identity() {
    let layout = GradLayout::from_sizes([
        ("conv.w".to_string(), 20),
        ("conv.b".to_string(), 4),
        ("fc.w".to_string(), 16),
    ]);
    let dim = layout.total();
    let budget = BudgetPolicy::Global { k: 8 };
    let non_matching = PolicyTable::parse("nothing_matches_*=dense").unwrap();
    for kind in all_kinds(dim) {
        let mut plain = LayerwiseSparsifier::new(&kind, layout.clone(), &budget, 1);
        let mut empty = LayerwiseSparsifier::with_policies(
            &kind,
            layout.clone(),
            &budget,
            &PolicyTable::default(),
            1,
        );
        let mut unmatched =
            LayerwiseSparsifier::with_policies(&kind, layout.clone(), &budget, &non_matching, 1);
        assert_eq!(plain.budgets(), empty.budgets(), "{kind:?}");
        assert_eq!(plain.budgets(), unmatched.budgets(), "{kind:?}");
        let mut rng = Rng::seed_from(17);
        let mut gagg = vec![0.0f32; dim];
        let (mut up_a, mut up_b, mut up_c) =
            (SparseUpdate::empty(), SparseUpdate::empty(), SparseUpdate::empty());
        for t in 0..6 {
            let g = rng.gaussian_vec(dim, 1.0);
            let genie: Option<Vec<f32>> =
                if plain.needs_genie() { Some(plain.peek_acc(&g)) } else { None };
            let ctx = RoundCtx {
                t,
                gagg_prev: &gagg,
                omega: 1.0 / 3.0,
                genie_acc: genie.as_deref(),
            };
            let view = GradView::new(&layout, &g);
            plain.step_group_into(&view, &ctx, &mut up_a);
            empty.step_group_into(&view, &ctx, &mut up_b);
            unmatched.step_group_into(&view, &ctx, &mut up_c);
            assert_eq!(up_a, up_b, "{kind:?} t={t} (empty table)");
            assert_eq!(up_a, up_c, "{kind:?} t={t} (non-matching table)");
            gagg = up_a.flatten().to_dense();
        }
    }
}

/// Heterogeneous end-to-end: the ISSUE spec example on a full trainer —
/// conv weights on RegTop-k, biases dense, everything else Top-k — with
/// per-group ledger attribution for both bytes and entries.
#[test]
fn heterogeneous_policy_end_to_end() {
    let params =
        LinearParams { workers: 4, rows_per_worker: 80, dim: 100, ..LinearParams::fig2() };
    let problem = generate(params, 11);
    let cfg = TrainConfig {
        workers: 4,
        eta: 0.02,
        sparsifier: SparsifierKind::RegTopK { k: 10, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(GradLayout::from_sizes([
            ("conv.w".to_string(), 60),
            ("conv.b".to_string(), 10),
            ("fc.w".to_string(), 30),
        ])),
        budget: Some(BudgetPolicy::Proportional { frac: 0.1 }),
        policy: Some(PolicyTable::parse("conv*.b=dense;conv*=regtopk:mu=0.3;*=topk").unwrap()),
        ..TrainConfig::default()
    };
    // the policy survives the config echo (manifest round trip)
    let cfg = TrainConfig::from_json(&cfg.to_json()).unwrap();
    assert!(cfg.policy.is_some());
    let mut tr = fig2::trainer_from_config(&cfg, &problem);
    assert_eq!(
        tr.workers[0].sparsifier.group_families(),
        vec!["regtopk", "dense", "topk"]
    );
    let iters = 60;
    for _ in 0..iters {
        let rr = tr.round();
        assert!(rr.mean_loss.is_finite());
    }
    let entries = tr.ledger.group_upload_entries();
    // conv.w: prop budget k=6; conv.b: dense (all 10); fc.w: k=3
    assert_eq!(entries[0], ("conv.w".to_string(), 6 * 4 * iters));
    assert_eq!(entries[1], ("conv.b".to_string(), 10 * 4 * iters));
    assert_eq!(entries[2], ("fc.w".to_string(), 3 * 4 * iters));
    let bytes = tr.ledger.group_upload_totals();
    assert_eq!(
        bytes.iter().map(|(_, b)| b).sum::<usize>(),
        tr.ledger.total_upload_bytes()
    );
    // and the threaded driver agrees under heterogeneous policies
    let mut b = fig2::trainer_from_config(&cfg, &problem);
    b.run_threaded(iters);
    assert_eq!(tr.server.w, b.server.w);
    assert_eq!(tr.ledger.group_upload_totals(), b.ledger.group_upload_totals());
}

/// A scheduled mu decay must (a) leave the trajectory identical when
/// the schedule is degenerate (from == to) and (b) actually change the
/// selection behavior when it decays.
#[test]
fn mu_schedule_equivalence_and_effect() {
    let params =
        LinearParams { workers: 3, rows_per_worker: 50, dim: 20, ..LinearParams::fig2() };
    let problem = generate(params, 5);
    let groups = GradLayout::from_sizes([("a".to_string(), 12), ("b".to_string(), 8)]);
    let mk = |policy: Option<PolicyTable>| TrainConfig {
        workers: 3,
        eta: 0.05,
        sparsifier: SparsifierKind::RegTopK { k: 4, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(groups.clone()),
        budget: Some(BudgetPolicy::Global { k: 4 }),
        policy,
        ..TrainConfig::default()
    };
    let mut plain = fig2::trainer_from_config(&mk(None), &problem);
    let degenerate = PolicyTable::parse("*=regtopk:mu=0.5..0.5/30").unwrap();
    let mut sched = fig2::trainer_from_config(&mk(Some(degenerate)), &problem);
    let decay = PolicyTable::parse("*=regtopk:mu=8.0..0.01/15").unwrap();
    let mut decayed = fig2::trainer_from_config(&mk(Some(decay)), &problem);
    for _ in 0..25 {
        plain.round();
        sched.round();
        decayed.round();
    }
    assert_eq!(
        plain.server.w, sched.server.w,
        "a constant schedule must not perturb the trajectory"
    );
    assert_ne!(
        plain.server.w, decayed.server.w,
        "a decaying mu schedule must alter selection"
    );
}

/// Property: for random multi-group layouts, the flat compatibility
/// path (`step_into`) of a layerwise stack equals its bucketed path
/// flattened, and every bucket respects its resolved budget.
#[test]
fn layerwise_flat_and_bucketed_paths_agree() {
    check::forall("layerwise_flat_vs_bucketed", |rng, _| {
        let ngroups = rng.below(4) + 1;
        let sizes: Vec<(String, usize)> =
            (0..ngroups).map(|g| (format!("g{g}"), rng.below(30) + 1)).collect();
        let layout = GradLayout::from_sizes(sizes);
        let dim = layout.total();
        let k = rng.below(dim) + 1;
        let kind = SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 };
        let budget = BudgetPolicy::Global { k };
        let mut a = LayerwiseSparsifier::new(&kind, layout.clone(), &budget, 0);
        let mut b = LayerwiseSparsifier::new(&kind, layout.clone(), &budget, 0);
        let budgets = a.budgets().to_vec();
        let mut gagg = vec![0.0f32; dim];
        let mut up = SparseUpdate::empty();
        for t in 0..4 {
            let g = check::arb_vec(rng, dim);
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.5, genie_acc: None };
            let flat = a.step(&g, &ctx);
            let view = GradView::new(&layout, &g);
            b.step_group_into(&view, &ctx, &mut up);
            assert_eq!(flat, up.flatten(), "t={t}");
            for (gi, bucket) in up.buckets().iter().enumerate() {
                let cap = budgets[gi].min(layout.group(gi).len);
                assert_eq!(bucket.nnz(), cap, "group {gi} budget");
                assert_eq!(bucket.dim(), layout.group(gi).len);
            }
            gagg = flat.to_dense();
        }
    });
}

/// A flat family sparsifier refuses a multi-group view (the default
/// trait path serves only the degenerate layout).
#[test]
#[should_panic]
fn flat_sparsifier_rejects_multi_group_view() {
    let layout = GradLayout::from_sizes([("a".to_string(), 2), ("b".to_string(), 2)]);
    let mut sp = build(&SparsifierKind::TopK { k: 1 }, 4, 0);
    let g = [1.0f32, 2.0, 3.0, 4.0];
    let z = [0.0f32; 4];
    let ctx = RoundCtx { t: 0, gagg_prev: &z, omega: 1.0, genie_acc: None };
    let view = GradView::new(&layout, &g);
    let mut up = SparseUpdate::empty();
    sp.step_group_into(&view, &ctx, &mut up);
}

/// Checkpoints carry the layout/budget through the config echo.
#[test]
fn checkpoint_roundtrip_preserves_grad_layout() {
    let params =
        LinearParams { workers: 3, rows_per_worker: 40, dim: 20, ..LinearParams::fig2() };
    let problem = generate(params, 3);
    let cfg = TrainConfig {
        workers: 3,
        eta: 0.02,
        sparsifier: SparsifierKind::RegTopK { k: 5, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(GradLayout::from_sizes([
            ("conv".to_string(), 12),
            ("fc".to_string(), 8),
        ])),
        budget: Some(BudgetPolicy::PerGroup { ks: vec![3, 2] }),
        ..TrainConfig::default()
    };
    let mut tr = fig2::trainer_from_config(&cfg, &problem);
    for _ in 0..3 {
        tr.round();
    }
    let ck = tr.checkpoint();
    let path = std::env::temp_dir()
        .join(format!("regtopk_layerwise_ckpt_{}.json", std::process::id()));
    ck.save(&path).unwrap();
    let re = Checkpoint::load(&path).unwrap();
    assert_eq!(re, ck);
    let cfg2 = TrainConfig::from_json(&re.config).unwrap();
    assert_eq!(cfg2.groups, cfg.groups, "layout must survive the checkpoint");
    assert_eq!(cfg2.budget, cfg.budget, "budget must survive the checkpoint");
    // restoring into a layout-identical trainer resumes the cursor
    let mut tr2 = fig2::trainer_from_config(&cfg2, &problem);
    tr2.restore(&re);
    assert_eq!(tr2.iter(), 3);
    assert_eq!(tr2.server.w, tr.server.w);
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(path.with_extension("w")).ok();
    std::fs::remove_file(path.with_extension("ef")).ok();
}

/// The acceptance scenario: multi-group RegTop-k with `Proportional`
/// budgets end-to-end, with per-group bytes in the ledger.
#[test]
fn multi_group_regtopk_proportional_end_to_end() {
    let params =
        LinearParams { workers: 4, rows_per_worker: 80, dim: 100, ..LinearParams::fig2() };
    let problem = generate(params, 11);
    let cfg = TrainConfig {
        workers: 4,
        eta: 0.02,
        sparsifier: SparsifierKind::RegTopK { k: 1, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(GradLayout::from_sizes([
            ("conv".to_string(), 60),
            ("fc".to_string(), 40),
        ])),
        budget: Some(BudgetPolicy::Proportional { frac: 0.1 }),
        ..TrainConfig::default()
    };
    let mut tr = fig2::trainer_from_config(&cfg, &problem);
    let initial_gap = fig2::opt_gap(&tr.server.w, &problem.w_star);
    for _ in 0..200 {
        let rr = tr.round();
        assert!(rr.mean_loss.is_finite());
    }
    // proportional 10% budgets: 6 + 4 entries per worker per round
    for r in tr.ledger.rounds() {
        assert_eq!(r.upload_entries, 4 * 10, "round {}", r.round);
    }
    let final_gap = fig2::opt_gap(&tr.server.w, &problem.w_star);
    assert!(final_gap < 0.9 * initial_gap, "{final_gap} !< 0.9*{initial_gap}");
    // per-group accounting: both groups carried bytes; totals add up
    let groups = tr.ledger.group_upload_totals();
    assert_eq!(groups.len(), 2);
    assert_eq!(groups[0].0, "conv");
    assert_eq!(groups[1].0, "fc");
    assert!(groups[0].1 > 0 && groups[1].1 > 0);
    assert_eq!(groups[0].1 + groups[1].1, tr.ledger.total_upload_bytes());
    // the conv group carries more budget (6 vs 4 entries/worker/round)
    assert!(groups[0].1 > groups[1].1);
}

/// The pooled threaded driver matches the deterministic driver under a
/// multi-group layout.
#[test]
fn threaded_driver_matches_deterministic_with_groups() {
    let params =
        LinearParams { workers: 3, rows_per_worker: 50, dim: 20, ..LinearParams::fig2() };
    let problem = generate(params, 5);
    let cfg = TrainConfig {
        workers: 3,
        eta: 0.05,
        sparsifier: SparsifierKind::TopK { k: 1 },
        eval_every: 0,
        groups: Some(GradLayout::from_sizes([
            ("a".to_string(), 12),
            ("b".to_string(), 8),
        ])),
        budget: Some(BudgetPolicy::PerGroup { ks: vec![3, 2] }),
        ..TrainConfig::default()
    };
    let mut a = fig2::trainer_from_config(&cfg, &problem);
    for _ in 0..12 {
        a.round();
    }
    let mut b = fig2::trainer_from_config(&cfg, &problem);
    b.run_threaded(12);
    assert_eq!(a.server.w, b.server.w);
    assert_eq!(a.ledger.total_upload_bytes(), b.ledger.total_upload_bytes());
    assert_eq!(a.ledger.group_upload_totals(), b.ledger.group_upload_totals());
}
