//! Public-API smoke test (PR 8 dead-pub gate companion): every item
//! here is part of the crate's intended surface — experiment drivers,
//! wire/resume types, and the small numeric utilities — and is
//! exercised end-to-end from outside the crate so the analyzer's
//! `dead-pub` rule sees a real cross-module reference, not a waiver.

use std::path::Path;

use regtopk::comm::codec::{quant_levels, QuantPayload, WirePayload};
use regtopk::comm::quantize::Quantizer;
use regtopk::comm::{CostModel, Ledger, RoundTraffic};
use regtopk::coordinator::{
    Checkpoint, DownlinkCodec, DownlinkState, EvalFn, RoundResult, TrainState,
};
use regtopk::data::cifar_like::{load_cifar10_bin, CLASSES};
use regtopk::data::linear::{generate, least_squares, solve_dense};
use regtopk::experiments::baselines::BaselineRow;
use regtopk::experiments::comm_table::{CommRow, MeasuredRow};
use regtopk::experiments::fig2::{run_curve_sharded, trainer_sharded};
use regtopk::experiments::fig3::{degraded_layout, Fig3Run};
use regtopk::experiments::sweeps::{hetero_layout, sweep_params, DownlinkRow, HeteroRow};
use regtopk::grad::{GradLayout, GroupSpec};
use regtopk::metrics::{IterRecord, RunLog};
use regtopk::optim::Adam;
use regtopk::runtime::{ArtifactSpec, DType, InputSpec, Manifest, ModelInfo};
use regtopk::sparsify::{
    glob_match, GroupPolicy, POLICY_KEYS, PolicyRule, PolicyTable, SparsifierKind, SparsifierState,
};
use regtopk::util::bench::BenchResult;
use regtopk::util::check::default_cases;
use regtopk::util::json::{Json, ParseError};
use regtopk::util::rng::{Rng, SplitMix64};

#[test]
fn wire_payload_and_quant_levels() {
    let wp = WirePayload::default();
    assert!(!wp.raw_index, "default payload is the raw-f32 bucket");
    assert_eq!(wp.value, QuantPayload::default());
    assert_eq!(quant_levels(4), 7);
    assert_eq!(quant_levels(2), 1);
}

#[test]
fn quantizer_returns_finite_scale() {
    let q = Quantizer::new(4);
    let mut vals = [1.0f32, -0.5, 0.25, 0.0];
    let mut rng = Rng::seed_from(7);
    let scale = q.quantize(&mut vals, &mut rng);
    assert!(scale.is_finite() && scale > 0.0);
}

#[test]
fn ledger_closes_rounds_with_traffic() {
    let mut led = Ledger::new(CostModel::default());
    led.close_round(0, 10, 2);
    let rt: &RoundTraffic = &led.rounds()[0];
    assert_eq!(rt.round, 0);
    assert!(rt.download_bytes > 0, "broadcast cost × workers is never free");
}

#[test]
fn checkpoint_state_roundtrips() {
    let dir = std::env::temp_dir().join(format!("regtopk-api-surface-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("ck.json");
    let state = TrainState {
        gagg_prev: vec![0.5, -1.0],
        workers: vec![SparsifierState::Stateless],
        downlink: Some(DownlinkState { rng: [1, 2, 3, 4], gauss_spare: None }),
    };
    let ck = Checkpoint::with_state(3, vec![0.25, 0.75], Json::parse("{}").unwrap(), state);
    ck.save(&path).expect("save");
    let back = Checkpoint::load(&path).expect("load");
    assert_eq!(back, ck, "save∘load is the identity, downlink section included");
    let legacy = Checkpoint::new(1, vec![1.0], Json::parse("{}").unwrap());
    assert_eq!(legacy.state, None);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn downlink_codec_accepts_empty_policy() {
    // unmatched groups broadcast raw, so the empty table is the
    // lossless default
    let table = PolicyTable::new(Vec::new()).expect("empty policy table");
    let layout = hetero_layout();
    assert_eq!(layout.total(), 60);
    let _codec = DownlinkCodec::new(&table, &layout, 9);
    assert_eq!(degraded_layout("mlp").total(), 378);
}

#[test]
fn eval_fn_is_object_safe() {
    let mut rec = IterRecord::new(3);
    let mut eval: Box<EvalFn> = Box::new(|t, w, r| {
        r.loss = w[0] + t as f32;
    });
    eval(1, &[0.5], &mut rec);
    assert_eq!(rec.loss, 1.5);
    let rr = RoundResult { t: 1, mean_loss: 0.5, upload_bytes: 640 };
    assert_eq!((rr.t, rr.upload_bytes), (1, 640));
}

#[test]
fn linear_testbed_solves_and_trains() {
    let problem = generate(sweep_params(2), 11);
    let ls = least_squares(&problem.shards);
    assert_eq!(ls.len(), problem.params.dim);
    for (a, b) in ls.iter().zip(&problem.w_star) {
        assert!((a - b).abs() < 1e-4, "least_squares matches the stored optimum");
    }

    let mut a = [2.0f64, 0.0, 0.0, 2.0];
    let mut b = [2.0f64, 4.0];
    solve_dense(&mut a, &mut b, 2);
    assert!((b[0] - 1.0).abs() < 1e-12 && (b[1] - 2.0).abs() < 1e-12);

    let _tr = trainer_sharded(&problem, SparsifierKind::TopK { k: 4 }, 0.05, 1);
    let log = run_curve_sharded(&problem, SparsifierKind::Dense, "dense-smoke", 2, 0.05, 1);
    assert_eq!(log.name, "dense-smoke");
}

#[test]
fn experiment_rows_construct() {
    let run = Fig3Run { log: RunLog::new("fig3", Json::parse("{}").unwrap()), groups: Vec::new() };
    assert!(run.groups.is_empty());
    assert_eq!(run.log.name, "fig3");

    let hr = HeteroRow {
        name: "regtopk".to_string(),
        final_gap: 0.1,
        bytes_per_round: 64,
        entries_per_round: 8,
    };
    let dr = DownlinkRow {
        name: "sparse".to_string(),
        final_gap: 0.2,
        up_bytes_per_round: 32,
        down_bytes_per_round: 16,
    };
    let br =
        BaselineRow { name: "topk".to_string(), final_gap: 0.3, bytes_per_round: 48, mean_k: 4.0 };
    let cr = CommRow {
        model: "mlp".to_string(),
        dim: 128,
        s: 0.01,
        symbols_per_epoch: 10.0,
        bytes_per_epoch: 40.0,
        compression: 3.2,
        idx_bound_bits: 7.0,
        rice_bits: 6.5,
    };
    let mr = MeasuredRow {
        name: "dense".to_string(),
        up_bytes: 512,
        down_bytes: 512,
        sim_s: 0.25,
        sock_up_bytes: 512,
        sock_down_bytes: 512,
    };
    assert!(hr.bytes_per_round > dr.down_bytes_per_round);
    assert!(br.mean_k > 0.0 && cr.compression > 1.0 && mr.sim_s > 0.0);
}

#[test]
fn grad_layout_exposes_group_specs() {
    let gl = GradLayout::from_sizes(vec![("a".to_string(), 4), ("b".to_string(), 6)]);
    let g: &GroupSpec = &gl.groups()[1];
    assert_eq!((g.name.as_str(), g.offset, g.len), ("b", 4, 6));
    assert_eq!(gl.total(), 10);
}

#[test]
fn adam_defaults_match_the_paper() {
    let adam = Adam::new(4, 0.1);
    assert_eq!((adam.beta1, adam.beta2), (0.9, 0.999));
    assert!(adam.eps > 0.0);
}

#[test]
fn manifest_registry_is_typed() {
    let mut man = Manifest::default();
    man.artifacts.insert(
        "loss".to_string(),
        ArtifactSpec {
            file: "loss.hlo".to_string(),
            inputs: vec![InputSpec { shape: vec![32, 10], dtype: DType::F32 }],
            outputs: 1,
            doc: "smoke fixture".to_string(),
        },
    );
    assert_eq!(man.artifacts["loss"].inputs[0].dtype, DType::F32);
    let none: Option<&ModelInfo> = man.models.get("mlp");
    assert!(none.is_none());
    assert!(Manifest::load(Path::new("/nonexistent/manifest.json")).is_err());
}

#[test]
fn cifar_loader_and_classes() {
    assert_eq!(CLASSES, 10);
    assert!(load_cifar10_bin(Path::new("/nonexistent-cifar"), &["data_batch_1.bin"]).is_none());
}

#[test]
fn policy_surface_globs_and_keys() {
    assert!(POLICY_KEYS.contains(&"bits") && POLICY_KEYS.contains(&"match"));
    assert!(glob_match("conv*", "conv1"));
    assert!(!glob_match("fc", "conv"));
    let rule = PolicyRule { pattern: "conv*".to_string(), policy: GroupPolicy::default() };
    let table = PolicyTable::new(vec![rule]).expect("one-rule table");
    assert_eq!(table.rules().len(), 1);
}

#[test]
fn small_utilities_hold() {
    let b = BenchResult { name: "flatten".to_string(), median_s: 0.001, elems: 1024 };
    assert!(b.median_s > 0.0 && b.elems > 0);
    assert!(default_cases() >= 1);

    let err: ParseError = Json::parse("{ nope").unwrap_err();
    assert!(!err.msg.is_empty());
    assert!(err.pos <= "{ nope".len());

    let mut a = SplitMix64(42);
    let mut b2 = SplitMix64(42);
    assert_eq!(a.next_u64(), b2.next_u64());
    let mut c = SplitMix64(43);
    assert_ne!(a.next_u64(), c.next_u64());
}
