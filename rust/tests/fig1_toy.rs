//! Fig. 1 integration: the paper's §1.2 toy reproduces its published
//! qualitative claims end-to-end through the coordinator stack.

use regtopk::experiments::fig1;
use regtopk::sparsify::SparsifierKind;

#[test]
fn top1_flat_for_the_papers_horizon() {
    // Paper: "TOP-1 is not able to reduce the empirical risk even after
    // 100 iterations" — with eta=0.9 the flat phase covers the figure.
    let logs = fig1::run(100, 0.5, 1.0);
    let top = logs.iter().find(|l| l.name == "topk").unwrap();
    let loss0 = fig1::risk(&fig1::W0);
    let flat = top
        .records()
        .iter()
        .take_while(|r| (r.loss - loss0).abs() < 1e-6)
        .count();
    assert!(flat >= 90, "TOP-1 flat for only {flat} iters");
}

#[test]
fn regtop1_tracks_dense_within_tolerance() {
    let logs = fig1::run(100, 0.5, 1.0);
    let f = |n: &str| logs.iter().find(|l| l.name == n).unwrap();
    let dense = f("dense");
    let reg = f("regtopk");
    // pointwise tracking after the first few iterations
    for t in (10..100).step_by(10) {
        let d = dense.records()[t].loss;
        let r = reg.records()[t].loss;
        // REGTOP-1 may run slightly AHEAD of dense (round-0 error
        // accumulation releases ~2x theta_2 mass at t=1); "tracks"
        // means within ~15% of the dense trajectory throughout.
        assert!(
            (r - d).abs() < 0.15 * d.max(0.01),
            "t={t}: regtopk {r} vs dense {d}"
        );
    }
}

#[test]
fn gtopk_genie_also_solves_the_toy() {
    // the §3.1 idealization: global TOP-1 transmits the constructive
    // entry from round 0
    let mut tr = fig1::toy_trainer(SparsifierKind::GlobalTopK { k: 1 }, 0.9, false);
    for _ in 0..30 {
        tr.round();
    }
    let loss = fig1::risk(&tr.server.w);
    assert!(loss < 0.05, "gtopk loss {loss}");
}

#[test]
fn randk_moves_but_slower_than_regtopk() {
    let mut rk = fig1::toy_trainer(SparsifierKind::RandK { k: 1, seed: 3 }, 0.9, false);
    let mut reg = fig1::toy_trainer(
        SparsifierKind::RegTopK { k: 1, mu: 0.5, q: 1.0 },
        0.9,
        false,
    );
    for _ in 0..30 {
        rk.round();
        reg.round();
    }
    let l_rk = fig1::risk(&rk.server.w);
    let l_reg = fig1::risk(&reg.server.w);
    // randk eventually transmits entry 2 half the time, so it moves,
    // but regtopk (which always finds it after round 0) is ahead
    assert!(l_reg <= l_rk + 1e-6, "regtopk {l_reg} vs randk {l_rk}");
}
