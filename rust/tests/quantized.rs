//! Contract of policy-driven quantized transmission (ISSUE 4
//! tentpole):
//!
//! 1. with NO `bits` override anywhere — no policy, an inherit-all
//!    `*=` rule, or an explicit `bits=32` passthrough — the grouped
//!    trainer is bit-identical across those spellings for ALL EIGHT
//!    sparsifier families, and no bucket ever carries a payload (the
//!    pre-quantization wire format survives untouched);
//! 2. a `bits` override makes the bucket's f32 values the exact decode
//!    of its packed payload, the ledger charges exactly the packed
//!    wire size (mixed widths included), and the rounding residual
//!    folds into the child's error store (conservation through the
//!    lossy wire);
//! 3. quantized training converges: the residual-in-EF trajectory
//!    keeps long-run transmitted mass equal to gradient mass, and the
//!    end-to-end gap stays in a sane band of the unquantized run at a
//!    fraction of the upload bytes;
//! 4. per-group `eta` scaling steps the scaled slice harder without
//!    touching the broadcast aggregate.

use regtopk::comm::codec::{index_bits, QuantPayload, WireCost};
use regtopk::comm::{CostModel, Ledger};
use regtopk::config::TrainConfig;
use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::fig2;
use regtopk::grad::{GradLayout, GradView};
use regtopk::comm::SparseUpdate;
use regtopk::sparsify::{
    BudgetPolicy, LayerwiseSparsifier, PolicyTable, RoundCtx, Sparsifier, SparsifierKind,
};

fn all_kinds(dim: usize) -> Vec<SparsifierKind> {
    let k = (dim / 4).max(1);
    vec![
        SparsifierKind::Dense,
        SparsifierKind::TopK { k },
        SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
        SparsifierKind::RandK { k, seed: 5 },
        SparsifierKind::Threshold { tau: 0.5 },
        SparsifierKind::GlobalTopK { k },
        SparsifierKind::Dgc { k, momentum: 0.9, clip: 0.0 },
        SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 2 * k },
    ]
}

fn grouped_layout() -> GradLayout {
    GradLayout::from_sizes([("conv.w".to_string(), 16), ("conv.b".to_string(), 8)])
}

/// Equivalence net: no `bits` override (in any spelling) must keep the
/// whole grouped path bit-identical to the pre-quantization tree — for
/// every family, through the full trainer.
#[test]
fn bits_unset_is_bit_identical_for_all_families() {
    let params =
        LinearParams { workers: 3, rows_per_worker: 60, dim: 24, ..LinearParams::fig2() };
    let problem = generate(params, 7);
    for kind in all_kinds(24) {
        let base = TrainConfig {
            workers: 3,
            eta: 0.03,
            sparsifier: kind.clone(),
            eval_every: 0,
            groups: Some(grouped_layout()),
            budget: Some(BudgetPolicy::Global { k: 6 }),
            ..TrainConfig::default()
        };
        // three spellings of "no quantization"
        let mut none = base.clone();
        none.policy = None;
        let mut inherit = base.clone();
        inherit.policy = Some(PolicyTable::parse("*=").unwrap());
        let mut passthrough = base.clone();
        passthrough.policy = Some(PolicyTable::parse("*=:bits=32").unwrap());
        let mut tr_none = fig2::trainer_from_config(&none, &problem);
        let mut tr_inherit = fig2::trainer_from_config(&inherit, &problem);
        let mut tr_pass = fig2::trainer_from_config(&passthrough, &problem);
        for _ in 0..15 {
            tr_none.round();
            tr_inherit.round();
            tr_pass.round();
        }
        assert_eq!(tr_none.server.w, tr_inherit.server.w, "{kind:?} inherit-rule");
        assert_eq!(tr_none.server.w, tr_pass.server.w, "{kind:?} bits=32");
        for (a, b) in tr_none.ledger.rounds().iter().zip(tr_pass.ledger.rounds()) {
            assert_eq!(a.upload_bytes, b.upload_bytes, "{kind:?} round {}", a.round);
        }
        assert_eq!(
            tr_none.ledger.group_upload_totals(),
            tr_pass.ledger.group_upload_totals(),
            "{kind:?}"
        );
    }
}

/// Every family accepts a `bits` override: the bucket decodes from its
/// payload, the conservation law survives the lossy wire for families
/// with an error store, and nothing panics for the rest.
#[test]
fn bits_override_works_for_every_family() {
    let dim = 24;
    let layout = grouped_layout();
    for kind in all_kinds(dim) {
        let table = PolicyTable::parse("*=:bits=4").unwrap();
        let mut lw = LayerwiseSparsifier::with_policies(
            &kind,
            layout.clone(),
            &BudgetPolicy::Global { k: 6 },
            &table,
            0,
        );
        let mut gagg = vec![0.0f32; dim];
        let mut up = SparseUpdate::empty();
        for t in 0..5 {
            let g: Vec<f32> =
                (0..dim).map(|i| ((i * 7 + t * 13) % 11) as f32 - 5.0).collect();
            let genie: Option<Vec<f32>> =
                lw.needs_genie().then(|| lw.peek_acc(&g));
            let ctx = RoundCtx {
                t,
                gagg_prev: &gagg,
                omega: 0.5,
                genie_acc: genie.as_deref(),
            };
            let view = GradView::new(&layout, &g);
            lw.step_group_into(&view, &ctx, &mut up);
            for gi in 0..up.num_buckets() {
                let bucket = up.bucket(gi);
                if bucket.nnz() == 0 {
                    assert!(up.quant(gi).is_none(), "{kind:?}: empty bucket, no payload");
                    continue;
                }
                match up.quant(gi) {
                    Some(q) => {
                        assert_eq!(q.bits(), 4, "{kind:?}");
                        assert_eq!(q.decode(), bucket.values(), "{kind:?} t={t} g={gi}");
                        // packing only happens when it pays on the wire
                        assert!(
                            q.wire_bytes(index_bits(bucket.dim())) < WireCost::paper().flat(bucket),
                            "{kind:?} t={t} g={gi}"
                        );
                    }
                    None => {
                        // raw fallback is legal exactly when packing
                        // would not shrink this bucket
                        assert!(
                            QuantPayload::bytes_for(bucket.nnz(), 4, index_bits(bucket.dim()))
                                >= WireCost::paper().flat(bucket),
                            "{kind:?} t={t} g={gi}: raw bucket though packing would pay"
                        );
                    }
                }
            }
            gagg = up.flatten().to_dense();
        }
    }
}

/// Ledger accounting equals the packed wire size exactly under MIXED
/// per-group bit widths, end to end through a real sparsifier stack.
#[test]
fn ledger_bytes_equal_packed_payload_sizes_mixed_widths() {
    let layout = GradLayout::from_sizes([
        ("a".to_string(), 16),
        ("b".to_string(), 16),
        ("c".to_string(), 16),
    ]);
    let table = PolicyTable::parse("a=topk:bits=4;b=topk:bits=8").unwrap();
    let mut lw = LayerwiseSparsifier::with_policies(
        &SparsifierKind::TopK { k: 9 },
        layout.clone(),
        &BudgetPolicy::Global { k: 9 },
        &table,
        0,
    );
    let cost = CostModel::default();
    let mut ledger = Ledger::new(cost);
    ledger.set_layout(&layout);
    let gagg = vec![0.0f32; 48];
    let mut up = SparseUpdate::empty();
    let mut want = [0usize; 3];
    for t in 0..6 {
        let g: Vec<f32> = (0..48).map(|i| ((i * 5 + t * 3) % 13) as f32 - 6.0).collect();
        let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
        let view = GradView::new(&layout, &g);
        lw.step_group_into(&view, &ctx, &mut up);
        ledger.record_update(&up);
        ledger.close_round(t, 48, 1);
        for gi in 0..3 {
            want[gi] += match up.quant(gi) {
                Some(q) => q.wire_bytes(index_bits(up.bucket(gi).dim())),
                None => cost.update_bytes(up.bucket(gi)),
            };
        }
    }
    let totals = ledger.group_upload_totals();
    for gi in 0..3 {
        assert_eq!(totals[gi].1, want[gi], "group {gi}");
    }
    // 4-bit < 8-bit < raw for identical budgets and group shapes
    assert!(totals[0].1 < totals[1].1 && totals[1].1 < totals[2].1, "{totals:?}");
}

/// The residual-in-EF trajectory: over many rounds of a constant
/// gradient, transmitted mass + residual error equals the total
/// gradient mass per entry — the lossy wire stays unbiased end to end.
#[test]
fn quantization_residual_conserves_mass_over_rounds() {
    let dim = 8;
    let layout = GradLayout::single(dim);
    let table = PolicyTable::parse("*=:bits=4").unwrap();
    let mut lw = LayerwiseSparsifier::with_policies(
        &SparsifierKind::TopK { k: 3 },
        layout.clone(),
        &BudgetPolicy::Global { k: 3 },
        &table,
        0,
    );
    let g = vec![1.0f32; dim];
    let gagg = vec![0.0f32; dim];
    let mut transmitted = vec![0.0f64; dim];
    let rounds = 200;
    let mut up = SparseUpdate::empty();
    for t in 0..rounds {
        let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
        let view = GradView::new(&layout, &g);
        lw.step_group_into(&view, &ctx, &mut up);
        for (i, v) in up.flatten().to_dense().iter().enumerate() {
            transmitted[i] += *v as f64;
        }
    }
    // eps = what is still owed; transmitted + eps == rounds * 1.0
    let zeros = vec![0.0f32; dim];
    let eps = lw.peek_acc(&zeros);
    for i in 0..dim {
        let total = transmitted[i] + eps[i] as f64;
        assert!(
            (total - rounds as f64).abs() < 0.5,
            "entry {i}: {total} vs {rounds}"
        );
    }
}

/// End-to-end: quantized training converges in a sane band of the
/// unquantized run while uploading a fraction of the bytes.
#[test]
fn quantized_training_converges_with_fewer_bytes() {
    let params =
        LinearParams { workers: 4, rows_per_worker: 100, dim: 40, ..LinearParams::fig2() };
    let problem = generate(params, 11);
    let layout =
        GradLayout::from_sizes([("fc0.w".to_string(), 32), ("fc0.b".to_string(), 8)]);
    let base = TrainConfig {
        workers: 4,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 10, mu: 0.5, q: 1.0 },
        eval_every: 1,
        groups: Some(layout),
        budget: Some(BudgetPolicy::Global { k: 10 }),
        ..TrainConfig::default()
    };
    let mut quant = base.clone();
    quant.policy = Some(PolicyTable::parse("*=:bits=5").unwrap());
    let mut tr_raw = fig2::trainer_from_config(&base, &problem);
    let mut tr_q = fig2::trainer_from_config(&quant, &problem);
    let log_raw = fig2::run_curve_with(&mut tr_raw, &problem, "raw", 250);
    let log_q = fig2::run_curve_with(&mut tr_q, &problem, "q5", 250);
    let gap_raw = log_raw.last().unwrap().opt_gap;
    let gap_q = log_q.last().unwrap().opt_gap;
    assert!(gap_q.is_finite() && gap_q < 6.0 * gap_raw.max(0.05), "{gap_q} vs {gap_raw}");
    let bytes_raw = tr_raw.ledger.total_upload_bytes();
    let bytes_q = tr_q.ledger.total_upload_bytes();
    assert!(
        (bytes_q as f64) < 0.55 * bytes_raw as f64,
        "quantized {bytes_q} vs raw {bytes_raw}"
    );
    // the manifest echo surfaces the resolved bit widths
    let echo = tr_q.config_echo();
    let resolved = echo.get("resolved").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(resolved[0].get("bits").and_then(|j| j.as_usize()), Some(5));
    assert_eq!(resolved[1].get("bits").and_then(|j| j.as_usize()), Some(5));
}

/// Per-group eta scaling (the §1.2 G-extension per layer): the scaled
/// group's slice steps exactly `eta_scale` times harder in round 0,
/// and the broadcast aggregate is untouched by the scaling.
#[test]
fn per_group_eta_scales_the_step_not_the_broadcast() {
    let params =
        LinearParams { workers: 3, rows_per_worker: 60, dim: 24, ..LinearParams::fig2() };
    let problem = generate(params, 5);
    let layout = grouped_layout();
    let base = TrainConfig {
        workers: 3,
        eta: 0.02,
        sparsifier: SparsifierKind::Dense,
        eval_every: 0,
        groups: Some(layout.clone()),
        budget: Some(BudgetPolicy::Global { k: 24 }),
        ..TrainConfig::default()
    };
    let mut scaled = base.clone();
    scaled.policy = Some(PolicyTable::parse("conv.b=:eta=3.0").unwrap());
    let mut tr_a = fig2::trainer_from_config(&base, &problem);
    let mut tr_b = fig2::trainer_from_config(&scaled, &problem);
    tr_a.round();
    tr_b.round();
    // same aggregate => the bias slice of the scaled run moved 3x
    // (up to one mul-reassociation ulp: the server scales g before
    // the eta mul, the test scales after)
    for i in 0..24 {
        let (da, db) = (tr_a.server.w[i], tr_b.server.w[i]);
        if i < 16 {
            assert_eq!(da, db, "unscaled slice i={i}");
        } else {
            let want = 3.0 * da;
            assert!(
                (db - want).abs() <= 1e-6 * want.abs().max(1e-9),
                "scaled slice i={i}: {db} vs {want}"
            );
        }
    }
    // the broadcast g^t is identical: round 2's inputs agree except
    // for the model, so compare the servers' gagg after round 1
    assert_eq!(tr_a.server.gagg, tr_b.server.gagg);
}
