//! Cross-layer equivalence: the rust-native sparsifier pipeline and
//! the L1/L2 HLO artifacts compute the same algorithm.
//!
//! This is the contract that lets the coordinator switch freely
//! between the native path (small J) and the artifact path (large J):
//! score agreement is checked entrywise AND at the selection level.

use regtopk::runtime::{Runtime, Tensor};
use regtopk::sparse::{select_topk, topk_threshold};
use regtopk::sparsify::RegTopK;
use regtopk::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    Runtime::open("artifacts").ok().or_else(|| {
        eprintln!("skipping: artifacts not built");
        None
    })
}

/// Full REGTOP-k round via artifacts (score -> host select -> EF) vs
/// the pure-rust `RegTopK` sparsifier over several synthetic rounds.
#[test]
fn multi_round_artifact_pipeline_matches_native_sparsifier() {
    let Some(mut rt) = runtime() else { return };
    let score_exe = rt.load("regtopk_score").unwrap();
    let ef_exe = rt.load("error_feedback").unwrap();
    let j = score_exe.spec.inputs[0].shape[0];
    let (k, omega, mu, q) = (64usize, 0.25f32, 0.5f32, 1.0f32);

    // native sparsifier
    let mut native = RegTopK::new(j, k, mu, q);
    // artifact-side state
    let mut eps = vec![0.0f32; j];
    let mut acc_prev = vec![0.0f32; j];
    let mut mask_prev = vec![0.0f32; j];
    let mut gagg_prev = vec![0.0f32; j];

    let mut rng = Rng::seed_from(77);
    for t in 0..4 {
        let g = rng.gaussian_vec(j, 1.0);

        // ---- artifact path
        let out = score_exe
            .call(&[
                Tensor::f32(eps.clone(), &[j]),
                Tensor::f32(g.clone(), &[j]),
                Tensor::f32(acc_prev.clone(), &[j]),
                Tensor::f32(gagg_prev.clone(), &[j]),
                Tensor::f32(mask_prev.clone(), &[j]),
                Tensor::f32(vec![omega, mu, q], &[3]),
            ])
            .unwrap();
        let (acc, score) = (&out[0], &out[1]);
        // round 0 is plain TOP-k (Alg. 1 line 1)
        let sel = if t == 0 { select_topk(acc, k) } else { select_topk(score, k) };
        let mut mask = vec![0.0f32; j];
        for &i in &sel {
            mask[i as usize] = 1.0;
        }
        let ef = ef_exe
            .call(&[Tensor::f32(acc.clone(), &[j]), Tensor::f32(mask.clone(), &[j])])
            .unwrap();
        let (ghat_art, eps_next) = (ef[0].clone(), ef[1].clone());
        acc_prev = acc.clone();
        mask_prev = mask;
        eps = eps_next;

        // ---- native path
        let ctx = regtopk::sparsify::RoundCtx {
            t,
            gagg_prev: &gagg_prev,
            omega,
            genie_acc: None,
        };
        use regtopk::sparsify::Sparsifier;
        let sv = native.step(&g, &ctx);

        // compare: same selection, same transmitted values
        assert_eq!(sv.indices(), sel.as_slice(), "t={t} selection");
        for (&i, &v) in sv.indices().iter().zip(sv.values()) {
            assert_eq!(v, ghat_art[i as usize], "t={t} value at {i}");
        }

        // fabricate the broadcast (single-worker "aggregate")
        let mut gagg = vec![0.0f32; j];
        sv.axpy_into(omega, &mut gagg);
        gagg_prev = gagg;
    }
}

/// Two-phase HLO-side selection (block_absmax threshold) equals exact
/// host selection when magnitudes are distinct.
#[test]
fn threshold_equals_exact_topk_on_artifact_scores() {
    let Some(mut rt) = runtime() else { return };
    let score_exe = rt.load("regtopk_score").unwrap();
    let j = score_exe.spec.inputs[0].shape[0];
    let mut rng = Rng::seed_from(5);
    let eps = rng.gaussian_vec(j, 1.0);
    let g = rng.gaussian_vec(j, 1.0);
    let z = vec![0.0f32; j];
    let out = score_exe
        .call(&[
            Tensor::f32(eps, &[j]),
            Tensor::f32(g, &[j]),
            Tensor::f32(z.clone(), &[j]),
            Tensor::f32(z.clone(), &[j]),
            Tensor::f32(z.clone(), &[j]),
            Tensor::f32(vec![0.25, 0.5, 1.0], &[3]),
        ])
        .unwrap();
    let score = &out[1];
    let k = 500;
    let exact = select_topk(score, k);
    let tau = topk_threshold(score, k);
    let by_threshold: Vec<u32> = score
        .iter()
        .enumerate()
        .filter(|(_, v)| v.abs() >= tau)
        .map(|(i, _)| i as u32)
        .collect();
    assert_eq!(exact, by_threshold);
}
