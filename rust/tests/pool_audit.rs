//! The unsafe-core proof suite (ISSUE 7): the debug-build `SharedSlice`
//! borrow auditor must catch the races the type system cannot, the
//! safe pool wrappers must partition exactly, and the repo-invariant
//! analyzer must (a) pass on this tree and (b) fire on one seeded
//! violation of every rule.
//!
//! The detector tests are compiled only when the auditor is (debug
//! builds or the `pool-audit` feature) — under `--release` without the
//! feature they would be undefined behavior, not a panic.

use regtopk::analysis;
use regtopk::util::check;
use regtopk::util::pool::{shard_range, SharedSlice, ThreadPool};

// ---------------------------------------------------------------- audit

#[test]
#[cfg(any(debug_assertions, feature = "pool-audit"))]
#[should_panic(expected = "overlapping")]
fn overlapping_shard_borrows_panic() {
    let pool = ThreadPool::new(2);
    let mut v = vec![0u32; 64];
    let sh = SharedSlice::new(&mut v);
    pool.run(2, |t| {
        // task 0 takes [0, 33), task 1 takes [32, 64): index 32 is
        // claimed twice.  Borrows are released at job end, so the
        // second registration always sees the first — the panic is
        // deterministic on every interleaving.
        let (lo, hi) = if t == 0 { (0, 33) } else { (32, 64) };
        // SAFETY: intentionally overlapping; the auditor panics before
        // the second aliasing view is materialized, so no two live
        // `&mut` ever coexist (this test only compiles in audit
        // builds).
        let part = unsafe { sh.range(lo, hi) };
        std::hint::black_box(part.len());
    });
}

#[test]
#[cfg(any(debug_assertions, feature = "pool-audit"))]
#[should_panic(expected = "use-after-join")]
fn use_after_join_panics() {
    let pool = ThreadPool::new(2);
    let mut v = vec![0u32; 16];
    let sh = SharedSlice::new(&mut v);
    pool.run(2, |t| {
        let (lo, hi) = shard_range(sh.len(), 2, t);
        // SAFETY: disjoint shard ranges within one job; `v` outlives
        // the `run` call.
        let part = unsafe { sh.range(lo, hi) };
        for x in part.iter_mut() {
            *x += 1;
        }
    });
    // the job is over: ranging the stale handle must panic
    // SAFETY: never materialized — the auditor panics first (this test
    // only compiles in audit builds).
    let _stale = unsafe { sh.range(0, 1) };
}

#[test]
fn touching_and_zero_length_ranges_are_allowed() {
    let pool = ThreadPool::new(2);
    let mut v = vec![0u32; 64];
    {
        let sh = SharedSlice::new(&mut v);
        pool.run(2, |t| {
            // exactly touching boundaries: [0, 32) and [32, 64)
            let (lo, hi) = if t == 0 { (0, 32) } else { (32, 64) };
            // SAFETY: touching ranges are disjoint; `v` outlives the run.
            let part = unsafe { sh.range(lo, hi) };
            for x in part.iter_mut() {
                *x = t as u32 + 1;
            }
            // SAFETY: zero-length views alias nothing.
            let empty = unsafe { sh.range(hi, hi) };
            assert!(empty.is_empty());
        });
    }
    assert!(v[..32].iter().all(|&x| x == 1));
    assert!(v[32..].iter().all(|&x| x == 2));
}

// ------------------------------------------------- safe-wrapper covers

fn check_cover(pool: &ThreadPool, dim: usize, shards: usize) {
    let mut v = vec![0u8; dim];
    pool.for_shards(&mut v, shards, |s, lo, part| {
        let (want_lo, want_hi) = shard_range(dim, shards, s);
        assert_eq!((lo, lo + part.len()), (want_lo, want_hi));
        for x in part.iter_mut() {
            *x += 1;
        }
    });
    // exact cover: every element written exactly once
    assert!(v.iter().all(|&x| x == 1), "dim={dim} shards={shards}");
}

#[test]
fn for_shards_partitions_are_exact_covers() {
    let pool = ThreadPool::new(3);
    // adversarial fixed pairs: empty/tiny dims, shards > dim, primes
    for &(dim, shards) in &[
        (0usize, 1usize),
        (0, 5),
        (1, 1),
        (1, 7),
        (5, 8),
        (7, 7),
        (64, 3),
        (97, 13),
        (1009, 31),
    ] {
        check_cover(&pool, dim, shards);
    }
    let max_dim = if cfg!(miri) { 200 } else { 2000 };
    check::forall("for_shards_cover", |rng, _| {
        let dim = rng.below(max_dim);
        let shards = rng.below(17) + 1;
        check_cover(&pool, dim, shards);
    });
}

#[test]
fn map_mut_touches_every_index_exactly_once() {
    let pool = ThreadPool::new(3);
    let max_n = if cfg!(miri) { 64 } else { 300 };
    check::forall("map_mut_cover", |rng, _| {
        let n = rng.below(max_n);
        let mut items: Vec<u32> = vec![0; n];
        let idxs = pool.map_mut(&mut items, |i, v| {
            *v += 1;
            i
        });
        assert_eq!(idxs, (0..n).collect::<Vec<_>>());
        assert!(items.iter().all(|&x| x == 1));
    });
}

// ------------------------------------------------------- analyzer gate

#[test]
fn analyzer_tree_is_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = analysis::analyze_tree(root).expect("tree walk");
    assert!(
        findings.is_empty(),
        "repo-invariant analyzer findings:\n{}",
        findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}

/// One seeded violation per per-line rule: the analyzer must report
/// exactly that rule, at the seeded line.  (The forbidden tokens below
/// live in string literals, which the analyzer's lexer blanks when it
/// scans THIS file — that asymmetry is itself part of what the suite
/// proves.)
#[test]
fn analyzer_catches_one_seeded_violation_per_rule() {
    let fixtures: &[(&str, &str, &str)] = &[
        ("safety-comment", "rust/src/util/pool.rs", "unsafe { f() }\n"),
        (
            "unsafe-allowlist",
            "rust/src/metrics/mod.rs",
            "// SAFETY: commented, but off-allowlist\nunsafe { f() }\n",
        ),
        (
            "spawn-outside-pool",
            "rust/src/coordinator/server.rs",
            "let h = std::thread::spawn(|| {});\n",
        ),
        (
            "byte-accounting",
            "rust/src/comm/ledger.rs",
            "let bytes = (nnz * bits).div_ceil(8);\n",
        ),
        (
            "wall-clock",
            "rust/src/sparsify/topk.rs",
            "let t0 = std::time::Instant::now();\n",
        ),
    ];
    for &(rule, path, src) in fixtures {
        let f = analysis::analyze_sources(&[(path.to_string(), src.to_string())]);
        assert_eq!(f.len(), 1, "{rule} fixture: {f:?}");
        assert_eq!(f[0].rule, rule, "{f:?}");
        assert_eq!(f[0].path, path);
        assert!(f[0].line > 0);
    }

    // kind-matrix is a tree rule: a family present in the enum but
    // missing from a matrix file must be reported against that file
    let enum_src = "pub enum SparsifierKind {\n    Dense,\n    TopK { k: usize },\n}\n";
    let full = "t(SparsifierKind::Dense); t(SparsifierKind::TopK { k: 1 });\n";
    let partial = "t(SparsifierKind::Dense);\n";
    let f = analysis::analyze_sources(&[
        ("rust/src/sparsify/mod.rs".to_string(), enum_src.to_string()),
        ("rust/tests/resume.rs".to_string(), full.to_string()),
        ("rust/tests/determinism.rs".to_string(), partial.to_string()),
    ]);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "kind-matrix");
    assert_eq!(f[0].path, "rust/tests/determinism.rs");
    assert!(f[0].msg.contains("TopK"));
}

/// The waiver escape hatch is rule-scoped and line-scoped.
#[test]
fn analyzer_waivers_are_scoped() {
    let waived = "// metric only — repro-lint: allow(wall-clock)\n\
                  let t0 = std::time::Instant::now();\n";
    let f = analysis::analyze_sources(&[(
        "rust/src/coordinator/server.rs".to_string(),
        waived.to_string(),
    )]);
    assert!(f.is_empty(), "{f:?}");
    // the same waiver does not excuse a different rule on that line
    let wrong = "// repro-lint: allow(wall-clock)\nlet b = x.div_ceil(8);\n";
    let f = analysis::analyze_sources(&[(
        "rust/src/coordinator/server.rs".to_string(),
        wrong.to_string(),
    )]);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "byte-accounting");
}
