//! PR 6 equivalence net — sparse-domain aggregation and the
//! compressed downlink:
//!
//! 1. the k·n sparse union merge is BIT-IDENTICAL to the dense
//!    densify-then-step server for all eight sparsifier families, flat
//!    and grouped/heterogeneous (the merge accumulates per-index
//!    contributions in the same worker order as the dense axpy loop,
//!    so the aggregates must be equal, not close);
//! 2. a lossless downlink codec (`*=`, `idx=rice`, `idx=raw`) changes
//!    only the wire representation: the trajectory stays bitwise equal
//!    to the downlink-free run while the ledger charges fewer
//!    broadcast bytes;
//! 3. for EVERY downlink codec family — lossless and quantized — a
//!    worker-side `GaggMirror` fed the sparse broadcast reconstructs
//!    exactly the dense g^t the server holds;
//! 4. the downlink axis composes with grouped layouts and a
//!    heterogeneous quantized uplink policy.

use regtopk::config::TrainConfig;
use regtopk::coordinator::GaggMirror;
use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::fig2;
use regtopk::grad::GradLayout;
use regtopk::sparsify::{BudgetPolicy, PolicyTable, SparsifierKind};

fn testbed() -> (LinearParams, u64) {
    (LinearParams { workers: 3, rows_per_worker: 50, dim: 24, ..LinearParams::fig2() }, 13)
}

fn all_kinds(dim: usize) -> Vec<SparsifierKind> {
    let k = (dim / 4).max(1);
    vec![
        SparsifierKind::Dense,
        SparsifierKind::TopK { k },
        SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
        SparsifierKind::RandK { k, seed: 5 },
        SparsifierKind::Threshold { tau: 0.5 },
        SparsifierKind::GlobalTopK { k },
        SparsifierKind::Dgc { k, momentum: 0.9, clip: 0.0 },
        SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 2 * k },
    ]
}

/// Drive the same config through the sparse-merge server and the
/// legacy dense path (`force_dense`); every round's aggregate, the
/// final model, and both ledger totals must agree bit for bit.
fn assert_sparse_equals_dense(tag: &str, cfg: &TrainConfig, rounds: usize) {
    let (params, seed) = testbed();
    let problem = generate(params, seed);
    let mut sparse = fig2::trainer_from_config(cfg, &problem);
    let mut dense = fig2::trainer_from_config(cfg, &problem);
    dense.server.force_dense = true;
    for t in 0..rounds {
        sparse.round();
        dense.round();
        assert_eq!(
            sparse.server.gagg, dense.server.gagg,
            "{tag}/{}: aggregate diverged at round {t}",
            cfg.sparsifier.name()
        );
    }
    assert_eq!(sparse.server.w, dense.server.w, "{tag}/{}", cfg.sparsifier.name());
    assert_eq!(
        sparse.ledger.total_upload_bytes(),
        dense.ledger.total_upload_bytes(),
        "{tag}/{}",
        cfg.sparsifier.name()
    );
    assert_eq!(
        sparse.ledger.total_download_bytes(),
        dense.ledger.total_download_bytes(),
        "{tag}/{}: downlink-unset must charge the dense broadcast",
        cfg.sparsifier.name()
    );
}

#[test]
fn sparse_merge_is_bit_identical_to_dense_aggregation_flat() {
    for kind in all_kinds(24) {
        let cfg = TrainConfig {
            workers: 3,
            eta: 0.03,
            sparsifier: kind,
            eval_every: 0,
            ..TrainConfig::default()
        };
        assert_sparse_equals_dense("flat", &cfg, 15);
    }
}

#[test]
fn sparse_merge_is_bit_identical_to_dense_aggregation_grouped() {
    for kind in all_kinds(24) {
        let cfg = TrainConfig {
            workers: 3,
            eta: 0.03,
            sparsifier: kind,
            eval_every: 0,
            groups: Some(GradLayout::from_sizes([
                ("conv.w".to_string(), 16),
                ("conv.b".to_string(), 8),
            ])),
            budget: Some(BudgetPolicy::Global { k: 6 }),
            ..TrainConfig::default()
        };
        assert_sparse_equals_dense("grouped", &cfg, 12);
    }
}

#[test]
fn sparse_merge_is_bit_identical_under_heterogeneous_policy() {
    // mixed families + a dense group + quantized transmission: the
    // merge has to reproduce partially-dense buckets and payload
    // decodes exactly
    let cfg = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 6, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(GradLayout::from_sizes([
            ("conv.w".to_string(), 12),
            ("conv.b".to_string(), 4),
            ("fc.w".to_string(), 8),
        ])),
        budget: Some(BudgetPolicy::Proportional { frac: 0.25 }),
        policy: Some(
            PolicyTable::parse("*.b=dense;conv*=regtopk:bits=4;*=topk").unwrap(),
        ),
        ..TrainConfig::default()
    };
    assert_sparse_equals_dense("hetero", &cfg, 12);
}

#[test]
fn lossless_downlink_keeps_the_trajectory_and_cuts_broadcast_bytes() {
    let (params, seed) = testbed();
    let problem = generate(params, seed);
    let base = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 2, mu: 0.5, q: 1.0 },
        eval_every: 0,
        ..TrainConfig::default()
    };
    let mut plain = fig2::trainer_from_config(&base, &problem);
    for _ in 0..15 {
        plain.round();
    }
    for spec in ["*=", "*=:idx=rice", "*=:idx=raw"] {
        let mut cfg = base.clone();
        cfg.downlink = Some(PolicyTable::parse(spec).unwrap());
        let mut tr = fig2::trainer_from_config(&cfg, &problem);
        for _ in 0..15 {
            tr.round();
        }
        // lossless codecs change the wire, not the math
        assert_eq!(tr.server.w, plain.server.w, "{spec}: model diverged");
        assert_eq!(tr.server.gagg, plain.server.gagg, "{spec}: aggregate diverged");
        // the uplink is untouched by the downlink axis
        assert_eq!(
            tr.ledger.total_upload_bytes(),
            plain.ledger.total_upload_bytes(),
            "{spec}"
        );
        // at k=2 of 24 the 3-worker union is <= 6 entries, far below
        // the dense 32J broadcast
        assert!(
            tr.ledger.total_download_bytes() < plain.ledger.total_download_bytes(),
            "{spec}: {} vs dense {}",
            tr.ledger.total_download_bytes(),
            plain.ledger.total_download_bytes()
        );
    }
}

#[test]
fn workers_reconstruct_the_broadcast_exactly_for_every_codec_family() {
    // the wire contract: whatever the downlink codec does to the
    // sparse g^t, scattering the broadcast into a worker-side mirror
    // must reproduce the server's dense g^t bit for bit — the server
    // steps on its own decode, so server and workers stay in lockstep
    // even under lossy value codecs
    let (params, seed) = testbed();
    let problem = generate(params, seed);
    for spec in
        ["*=", "*=:idx=rice", "*=:idx=raw", "*=:bits=8", "*=:bits=8,idx=rice,levels=nuq"]
    {
        let cfg = TrainConfig {
            workers: 3,
            eta: 0.03,
            sparsifier: SparsifierKind::RegTopK { k: 6, mu: 0.5, q: 1.0 },
            eval_every: 0,
            downlink: Some(PolicyTable::parse(spec).unwrap()),
            ..TrainConfig::default()
        };
        let mut tr = fig2::trainer_from_config(&cfg, &problem);
        let mut mirror = GaggMirror::new(24);
        for _ in 0..12 {
            let rr = tr.round();
            assert!(rr.mean_loss.is_finite(), "{spec}");
            mirror.apply(tr.server.gagg_sparse());
            assert_eq!(mirror.dense(), tr.server.gagg.as_slice(), "{spec}");
        }
    }
}

#[test]
fn downlink_composes_with_grouped_hetero_quantized_uplink() {
    let (params, seed) = testbed();
    let problem = generate(params, seed);
    let rounds = 20;
    let cfg = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 6, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(GradLayout::from_sizes([
            ("conv.w".to_string(), 12),
            ("conv.b".to_string(), 4),
            ("fc.w".to_string(), 8),
        ])),
        budget: Some(BudgetPolicy::Proportional { frac: 0.25 }),
        policy: Some(
            PolicyTable::parse("*.b=dense;conv*=regtopk:bits=4;*=topk").unwrap(),
        ),
        downlink: Some(PolicyTable::parse("*=:bits=8,idx=rice").unwrap()),
        ..TrainConfig::default()
    };
    let mut tr = fig2::trainer_from_config(&cfg, &problem);
    for _ in 0..rounds {
        let rr = tr.round();
        assert!(rr.mean_loss.is_finite());
    }
    // the ISSUE acceptance bar: downlink bytes below the dense 32·J
    // per-worker baseline
    let dense_baseline = tr.ledger.cost.broadcast_bytes(24) * 3 * rounds;
    let down = tr.ledger.total_download_bytes();
    assert!(down < dense_baseline, "downlink {down} vs dense baseline {dense_baseline}");
}
