//! Checkpoint-resume contract (ISSUE 3 satellite): a run restored from
//! a checkpoint must continue the EXACT trajectory of the
//! uninterrupted run — model, aggregate history, error feedback,
//! selection RNG streams — for every sparsifier family, flat and
//! layer-wise.  Before this fix `Trainer::restore` dropped `gagg_prev`
//! and all sparsifier state, silently degrading resumed RegTop-k to a
//! cold plain-Top-k restart.

use regtopk::config::TrainConfig;
use regtopk::coordinator::Checkpoint;
use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::fig2;
use regtopk::grad::GradLayout;
use regtopk::sparsify::{BudgetPolicy, PolicyTable, SparsifierKind};

fn testbed() -> (LinearParams, u64) {
    (LinearParams { workers: 3, rows_per_worker: 50, dim: 24, ..LinearParams::fig2() }, 13)
}

fn all_kinds(dim: usize) -> Vec<SparsifierKind> {
    let k = (dim / 4).max(1);
    vec![
        SparsifierKind::Dense,
        SparsifierKind::TopK { k },
        SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
        SparsifierKind::RandK { k, seed: 5 },
        SparsifierKind::Threshold { tau: 0.5 },
        SparsifierKind::GlobalTopK { k },
        SparsifierKind::Dgc { k, momentum: 0.9, clip: 0.0 },
        SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 2 * k },
    ]
}

/// Drive `total` rounds uninterrupted vs `split` rounds + checkpoint +
/// restore-into-fresh-trainer + the remaining rounds; the final models
/// and aggregates must agree bit for bit.  `tag` keeps the on-disk
/// checkpoint paths distinct across concurrently running tests.
fn assert_resume_exact(tag: &str, cfg: &TrainConfig, split: usize, total: usize) {
    let (params, seed) = testbed();
    let problem = generate(params, seed);
    let mut full = fig2::trainer_from_config(cfg, &problem);
    for _ in 0..total {
        full.round();
    }
    let mut first = fig2::trainer_from_config(cfg, &problem);
    for _ in 0..split {
        first.round();
    }
    let ck = first.checkpoint();
    assert!(ck.state.is_some(), "trainer checkpoints must carry resume state");
    // round-trip through disk: the sidecar codec is part of the contract
    let path = std::env::temp_dir().join(format!(
        "regtopk_resume_{}_{tag}_{}.json",
        std::process::id(),
        cfg.sparsifier.name()
    ));
    ck.save(&path).unwrap();
    let re = Checkpoint::load(&path).unwrap();
    assert_eq!(re, ck);
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(path.with_extension("w")).ok();
    std::fs::remove_file(path.with_extension("ef")).ok();

    let mut resumed = fig2::trainer_from_config(cfg, &problem);
    resumed.restore(&re);
    assert_eq!(resumed.iter(), split);
    for _ in split..total {
        resumed.round();
    }
    assert_eq!(
        full.server.w, resumed.server.w,
        "{}: resumed model diverged from the uninterrupted run",
        cfg.sparsifier.name()
    );
}

#[test]
fn resume_equals_uninterrupted_for_all_families_flat() {
    for kind in all_kinds(24) {
        let cfg = TrainConfig {
            workers: 3,
            eta: 0.03,
            sparsifier: kind,
            eval_every: 0,
            ..TrainConfig::default()
        };
        assert_resume_exact("flat", &cfg, 6, 14);
    }
}

#[test]
fn resume_equals_uninterrupted_layerwise_regtopk() {
    let cfg = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 6, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(GradLayout::from_sizes([
            ("conv.w".to_string(), 16),
            ("conv.b".to_string(), 8),
        ])),
        budget: Some(BudgetPolicy::Global { k: 6 }),
        ..TrainConfig::default()
    };
    assert_resume_exact("layerwise", &cfg, 5, 12);
}

#[test]
fn resume_equals_uninterrupted_heterogeneous_policy() {
    // mixed families AND a mu schedule: schedules are functions of the
    // restored cursor t, so the resumed run re-derives the same mu
    let cfg = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 6, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(GradLayout::from_sizes([
            ("conv.w".to_string(), 12),
            ("conv.b".to_string(), 4),
            ("fc.w".to_string(), 8),
        ])),
        budget: Some(BudgetPolicy::Proportional { frac: 0.25 }),
        policy: Some(
            PolicyTable::parse("*.b=dense;conv*=regtopk:mu=0.8..0.2/10;*=topk").unwrap(),
        ),
        ..TrainConfig::default()
    };
    assert_resume_exact("hetero", &cfg, 4, 11);
}

#[test]
fn resume_equals_uninterrupted_quantized_transmission() {
    // ISSUE 4: the quantizer's stochastic-rounding stream travels in
    // the `.ef` sidecar (SparsifierState::Quantized), so a resumed
    // quantized run re-draws exactly the rounding decisions — and the
    // residual-in-EF history — the uninterrupted run would have.
    let cfg = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 6, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(GradLayout::from_sizes([
            ("conv.w".to_string(), 12),
            ("conv.b".to_string(), 4),
            ("fc.w".to_string(), 8),
        ])),
        budget: Some(BudgetPolicy::Proportional { frac: 0.25 }),
        policy: Some(
            PolicyTable::parse("*.b=dense;conv*=regtopk:bits=4;*=topk:bits=8..4/8").unwrap(),
        ),
        ..TrainConfig::default()
    };
    assert_resume_exact("quantized", &cfg, 5, 13);
}

#[test]
fn resume_equals_uninterrupted_codec_stack() {
    // ISSUE 5: the codec stack checkpoints cleanly — the Rice index
    // codec and NUQ levels are stateless per round, and the
    // residual-steered `bits=auto` width travels in the `.ef` sidecar
    // (SparsifierState::Quantized.auto_bits, tag 7) so a resumed run
    // continues at exactly the width the uninterrupted run reached.
    let cfg = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 6, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(GradLayout::from_sizes([
            ("conv.w".to_string(), 12),
            ("conv.b".to_string(), 4),
            ("fc.w".to_string(), 8),
        ])),
        budget: Some(BudgetPolicy::Proportional { frac: 0.25 }),
        policy: Some(
            PolicyTable::parse(
                "*.b=dense;conv*=regtopk:bits=auto:4..8,idx=rice;*=topk:bits=5,levels=nuq",
            )
            .unwrap(),
        ),
        ..TrainConfig::default()
    };
    assert_resume_exact("codec", &cfg, 5, 13);
}

#[test]
fn resume_equals_uninterrupted_with_downlink_compression() {
    // ISSUE 6: a quantized downlink draws from its own RNG stream
    // every round, so the `.ef` sidecar carries the codec's RNG in the
    // additive DLNK section — a resumed run must re-draw exactly the
    // broadcast rounding decisions the uninterrupted run would have.
    // A lossless spec rides along to pin the stream-free case too.
    for spec in ["*=:bits=8,idx=rice", "*=:idx=rice"] {
        let cfg = TrainConfig {
            workers: 3,
            eta: 0.03,
            sparsifier: SparsifierKind::RegTopK { k: 6, mu: 0.5, q: 1.0 },
            eval_every: 0,
            downlink: Some(PolicyTable::parse(spec).unwrap()),
            ..TrainConfig::default()
        };
        assert_resume_exact("downlink", &cfg, 5, 13);
    }
}

#[test]
fn legacy_model_only_checkpoint_still_restores_cold() {
    let (params, seed) = testbed();
    let problem = generate(params, seed);
    let cfg = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 6, mu: 0.5, q: 1.0 },
        eval_every: 0,
        ..TrainConfig::default()
    };
    let mut tr = fig2::trainer_from_config(&cfg, &problem);
    for _ in 0..4 {
        tr.round();
    }
    // a pre-fix checkpoint: model + cursor only
    let legacy = Checkpoint::new(tr.iter(), tr.server.w.clone(), cfg.to_json());
    let mut resumed = fig2::trainer_from_config(&cfg, &problem);
    resumed.restore(&legacy);
    assert_eq!(resumed.iter(), 4);
    assert_eq!(resumed.server.w, tr.server.w);
    // cold error feedback: the next round still runs fine
    let rr = resumed.round();
    assert!(rr.mean_loss.is_finite());
}

#[test]
fn restore_rejects_mismatched_worker_state() {
    let (params, seed) = testbed();
    let problem = generate(params, seed);
    let topk = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::TopK { k: 6 },
        eval_every: 0,
        ..TrainConfig::default()
    };
    let mut tr = fig2::trainer_from_config(&topk, &problem);
    tr.round();
    let ck = tr.checkpoint();
    // importing an Ef state into a dgc stack must fail loudly
    let dgc = TrainConfig {
        sparsifier: SparsifierKind::Dgc { k: 6, momentum: 0.9, clip: 0.0 },
        ..topk.clone()
    };
    let mut other = fig2::trainer_from_config(&dgc, &problem);
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        other.restore(&ck);
    }));
    assert!(res.is_err(), "family-mismatched resume state must not restore silently");
}
