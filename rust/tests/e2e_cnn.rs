//! End-to-end smoke of the Fig. 3 pipeline: artifact-backed ResNet-8
//! training through the full coordinator stack (data gen -> shard ->
//! PJRT grad -> sparsify -> aggregate -> SGD -> eval).  The full-length
//! run lives in examples/cnn_train.rs; this test keeps iterations small.

use regtopk::experiments::fig3::{run, Fig3Config};
use regtopk::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    Runtime::open("artifacts").ok().or_else(|| {
        eprintln!("skipping: artifacts not built");
        None
    })
}

#[test]
fn resnet8_short_training_descends_and_evaluates() {
    let Some(mut rt) = runtime() else { return };
    let cfg = Fig3Config {
        workers: 4,
        iters: 12,
        eval_every: 6,
        train_rows: 320,
        val_rows: 100,
        s: 0.01,
        ..Fig3Config::default()
    };
    let runs = run(&mut rt, &cfg, "resnet8", false).unwrap();
    assert_eq!(runs.len(), 2);
    for r in &runs {
        let log = &r.log;
        let first = log.records()[0].loss;
        let last = log.last().unwrap().loss;
        assert!(first.is_finite() && last.is_finite(), "{}", log.name);
        // some accuracy evaluation happened and is a valid probability
        let acc = log
            .records()
            .iter()
            .rev()
            .find(|r| !r.accuracy.is_nan())
            .map(|r| r.accuracy)
            .expect("no eval record");
        assert!((0.0..=1.0).contains(&acc), "{}: acc {acc}", log.name);
        // training signal: loss at end below the start (12 iters of a
        // fresh CNN on separable synthetic data moves fast)
        assert!(last < first, "{}: {first} -> {last}", log.name);
    }
}

#[test]
fn mlp_path_trains_too() {
    let Some(mut rt) = runtime() else { return };
    let cfg = Fig3Config {
        workers: 2,
        iters: 8,
        eval_every: 0,
        train_rows: 200,
        val_rows: 100,
        s: 0.001,
        ..Fig3Config::default()
    };
    let runs = run(&mut rt, &cfg, "mlp", false).unwrap();
    for r in &runs {
        let log = &r.log;
        assert!(log.last().unwrap().loss < log.records()[0].loss, "{}", log.name);
    }
}

#[test]
fn resnet8_layerwise_adopts_manifest_layout() {
    // the tentpole wiring: `GradLayout::from_flat` on the artifact's
    // real per-layer layout, per-layer ledger tables on the way out
    let Some(mut rt) = runtime() else { return };
    let cfg = Fig3Config {
        workers: 2,
        iters: 4,
        eval_every: 0,
        train_rows: 200,
        val_rows: 100,
        s: 0.01,
        layerwise: true,
        ..Fig3Config::default()
    };
    let runs = run(&mut rt, &cfg, "resnet8", false).unwrap();
    let layers = rt.manifest.models["resnet8"].layout.layers.len();
    for r in &runs {
        assert!(r.log.last().unwrap().loss.is_finite());
        assert_eq!(r.groups.len(), layers, "one ledger row per manifest layer");
        assert!(r.groups.iter().all(|(_, _, b, _)| *b > 0));
    }
}

#[test]
fn identical_seeds_give_identical_batches_across_sparsifiers() {
    // §4.2 fairness: topk and regtopk runs share init + batch sequence,
    // so their round-0 losses (computed before any update) are EQUAL.
    let Some(mut rt) = runtime() else { return };
    let cfg = Fig3Config {
        workers: 2,
        iters: 1,
        eval_every: 0,
        train_rows: 200,
        val_rows: 100,
        ..Fig3Config::default()
    };
    let runs = run(&mut rt, &cfg, "resnet8", false).unwrap();
    assert_eq!(
        runs[0].log.records()[0].loss.to_bits(),
        runs[1].log.records()[0].loss.to_bits()
    );
}
