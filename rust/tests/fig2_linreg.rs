//! Fig. 2 integration at the paper's FULL geometry (N=20, D=500,
//! J=100, eta=0.01, U=0, sigma^2=5, h^2=1, eps=0.5).
//!
//! Reproduction findings (EXPERIMENTS.md §Fig2): two of the figure's
//! three curve shapes reproduce exactly — dense GD converges to w*
//! and TOP-k plateaus at a fixed optimality gap ("oscillates at a
//! fixed optimality gap", §4.1).  The third claim (REGTOP-k tracking
//! dense at S=0.6) does NOT reproduce from Algorithm 1 as printed:
//! REGTOP-k tracks TOP-k at parity across mu in [0.1, 50] and
//! Q in {0, 1, N-1}.  Alg. 1's posterior distortion has one-round
//! memory, so it can suppress at most k destructively-aggregating
//! coordinates per round; in the isotropic-heterogeneity generator
//! every coordinate is destructive near w*, and the suppression has
//! no selection signal to exploit.  The separation the paper builds
//! its intuition on (§1.2) DOES reproduce whenever the destructive
//! set is small relative to k — see fig1_toy.rs.  These tests pin the
//! reproducible claims.

use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::fig2;
use regtopk::metrics::RunLog;
use regtopk::sparsify::SparsifierKind;

fn curve(problem: &regtopk::data::linear::LinearProblem, kind: SparsifierKind, iters: usize) -> RunLog {
    fig2::run_curve(problem, kind, "x", iters, fig2::ETA)
}

fn tail_gap(log: &RunLog) -> f32 {
    let recs = log.records();
    let tail = &recs[recs.len() - 200..];
    tail.iter().map(|r| r.opt_gap).sum::<f32>() / tail.len() as f32
}

#[test]
#[cfg_attr(debug_assertions, ignore = "full-geometry run; use cargo test --release")]
fn dense_converges_and_topk_plateaus() {
    let problem = generate(LinearParams::fig2(), 42);
    let iters = 2500;
    let dense = curve(&problem, SparsifierKind::Dense, iters);
    let top = curve(&problem, SparsifierKind::TopK { k: 60 }, iters);
    let dense_gap = tail_gap(&dense);
    let top_gap = tail_gap(&top);
    // dense: converged to the LS optimum
    assert!(dense_gap < 1e-3, "dense gap {dense_gap}");
    // TOP-k: stuck at a fixed distance, orders of magnitude above dense
    assert!(top_gap > 50.0 * dense_gap, "topk {top_gap} vs dense {dense_gap}");
    // ... and it is a plateau, not divergence: gap stable over the tail
    let g1000 = top.records()[1000].opt_gap;
    assert!(top_gap < 3.0 * g1000 && top_gap > 0.2 * g1000, "{g1000} -> {top_gap}");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "full-geometry run; use cargo test --release")]
fn regtopk_is_at_parity_with_topk_at_equal_budget() {
    // The reproducible Fig.2 statement for REGTOP-k on this testbed:
    // identical communication budget, final gap within 50% of TOP-k
    // (parity), never divergent.
    let problem = generate(LinearParams::fig2(), 42);
    let iters = 2500;
    for s in [0.4f64, 0.6] {
        let k = (s * 100.0) as usize;
        let top = curve(&problem, SparsifierKind::TopK { k }, iters);
        let reg = curve(
            &problem,
            SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
            iters,
        );
        let (tg, rg) = (tail_gap(&top), tail_gap(&reg));
        assert!(rg < 1.5 * tg, "S={s}: regtopk {rg} vs topk {tg}");
        assert!(rg.is_finite() && rg > 0.0);
        assert_eq!(
            top.records()[10].upload_bytes,
            reg.records()[10].upload_bytes,
            "budgets must match at S={s}"
        );
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "full-geometry run; use cargo test --release")]
fn higher_sparsity_budget_lowers_the_plateau() {
    // the cross-panel trend of Fig. 2: S=0.6 plateaus below S=0.4
    let problem = generate(LinearParams::fig2(), 42);
    let lo = curve(&problem, SparsifierKind::TopK { k: 40 }, 2500);
    let hi = curve(&problem, SparsifierKind::TopK { k: 60 }, 2500);
    assert!(tail_gap(&hi) < tail_gap(&lo));
}

#[test]
#[cfg_attr(debug_assertions, ignore = "full-geometry run; use cargo test --release")]
fn gtopk_genie_beats_local_topk() {
    // §3.1's idealized bound: selecting by the TRUE aggregate removes
    // the destructive-selection waste and lowers the plateau.
    let params = LinearParams { workers: 10, rows_per_worker: 200, dim: 60, ..LinearParams::fig2() };
    let problem = generate(params, 7);
    let k = 12; // S = 0.2: tight budget, selection quality matters
    let top = curve(&problem, SparsifierKind::TopK { k }, 2000);
    let genie = curve(&problem, SparsifierKind::GlobalTopK { k }, 2000);
    assert!(
        tail_gap(&genie) < tail_gap(&top),
        "gtopk {} !< topk {}",
        tail_gap(&genie),
        tail_gap(&top)
    );
}
