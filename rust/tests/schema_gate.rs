//! Integration gates for the PR 8 semantic analysis pass: the golden
//! SCHEMA lock fixture, one seeded violation per new rule (tag
//! renumber, layer cycle, wildcard match), and the item-extractor
//! surface those gates are built on.  `analysis::tests` covers the
//! rule internals; this file pins the *public* analyzer API that
//! `repro lint` and scripts/ci.sh drive.

use std::path::Path;

use regtopk::analysis::extract::{
    extract, is_wildcard_head, parse_all, strip_guard, ConstItem, EnumItem, FileItems, MatchArm,
    MatchSite, PubItem, SourceFile, StructItem, UseEdge,
};
use regtopk::analysis::graph::{dead_pubs, layering, module_of, LAYERS};
use regtopk::analysis::lexer::{has_word, split, Line};
use regtopk::analysis::rules::{analyze_parsed, parse_kind_variants};
use regtopk::analysis::schema::{check_tree, compare, current, parse_lock, render, Schema, Section};
use regtopk::analysis::{
    analyze_sources, analyze_tree_full, read_tree, Finding, RULES, TreeReport, UNSAFE_ALLOWLIST,
};

fn src_files(files: &[(&str, &str)]) -> Vec<(String, String)> {
    files.iter().map(|(p, s)| ((*p).to_string(), (*s).to_string())).collect()
}

#[test]
fn lexer_separates_three_channels() {
    let lines = split("let tag = \"RTKS\"; // trailing note\nlet c = 'x';\n");
    let l: &Line = &lines[0];
    assert!(!l.code.contains("RTKS"), "string contents are blanked in code: {:?}", l.code);
    assert!(l.text.contains("RTKS"), "string contents survive in text: {:?}", l.text);
    assert!(l.comment.contains("trailing note"));
    assert!(!l.code.contains("trailing"), "comments never reach the code channel");
    assert!(has_word(&l.code, "tag"));
    assert!(!has_word("foobar", "foo"), "has_word is identifier-bounded");
    assert!(has_word("a.quantize(x)", "quantize"));
    // char literal on line 2 is blanked but keeps token structure
    assert!(lines[1].code.contains('\''));
    assert!(!lines[1].code.contains('x'));
}

#[test]
fn extractor_builds_the_item_model() {
    let src = concat!(
        "pub const MAGIC: &[u8; 4] = b\"RTKS\";\n",
        "\n",
        "pub enum Wire {\n",
        "    Dense { w: Vec<f32> },\n",
        "    Sparse(u32),\n",
        "}\n",
        "\n",
        "pub struct Pkt {\n",
        "    pub seq: u32,\n",
        "    crc: u32,\n",
        "}\n",
        "\n",
        "use crate::util::json;\n",
        "\n",
        "fn route(m: u32) -> u32 {\n",
        "    match m {\n",
        "        0 => 1,\n",
        "        n if n > 9 => 9,\n",
        "        other => other,\n",
        "    }\n",
        "}\n",
    );
    let file = SourceFile::parse("rust/src/comm/fixture.rs", src);
    let items: FileItems = extract(&file);

    let e: &EnumItem = &items.enums[0];
    assert_eq!(e.name, "Wire");
    assert_eq!(e.variants.len(), 2);
    assert_eq!(e.variants[0].0, "Dense { w: Vec<f32> }");

    let s: &StructItem = &items.structs[0];
    assert_eq!(s.name, "Pkt");
    assert_eq!(s.fields.len(), 2);

    let c: &ConstItem = &items.consts[0];
    assert_eq!(c.name, "MAGIC");
    assert!(c.value.contains("RTKS"), "text channel keeps the literal: {:?}", c.value);

    let m: &MatchSite = &items.matches[0];
    assert_eq!(m.arms.len(), 3);
    let guarded: &MatchArm = &m.arms[1];
    assert_eq!(strip_guard(&guarded.head), "n");
    assert!(is_wildcard_head(&m.arms[2].head));
    assert!(is_wildcard_head("_"));
    assert!(!is_wildcard_head("Wire::Dense { .. }"));
    assert!(!is_wildcard_head("true"), "bool matches are exhaustive without wildcards");

    let u: &UseEdge = &items.uses[0];
    assert_eq!(u.module, "util");

    let p: &PubItem = &items.pubs[0];
    assert_eq!((p.kind.as_str(), p.name.as_str()), ("const", "MAGIC"));
    let names: Vec<&str> = items.pubs.iter().map(|p| p.name.as_str()).collect();
    assert_eq!(names, ["MAGIC", "Wire", "Pkt"], "private `route` is not a pub item");
}

#[test]
fn wildcard_gate_fires_and_waives() {
    let bad = concat!(
        "fn route(m: &Msg) -> u32 {\n",
        "    match m {\n",
        "        Msg::Dense { .. } => 1,\n",
        "        _ => 0,\n",
        "    }\n",
        "}\n",
    );
    let files = src_files(&[("rust/src/comm/fixture.rs", bad)]);
    let f = analyze_sources(&files);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("wildcard", 4));

    let waived =
        bad.replace("        _ => 0,", "        // repro-lint: allow(wildcard)\n        _ => 0,");
    let files = src_files(&[("rust/src/comm/fixture.rs", waived.as_str())]);
    assert!(analyze_sources(&files).is_empty(), "waiver clears the gate");
    let all = analyze_parsed(&parse_all(&files));
    assert!(
        all.iter().any(|f| f.rule == "wildcard" && f.waived),
        "waived finding stays visible for --json: {all:?}"
    );
}

#[test]
fn layering_gate_rejects_upward_edges_and_cycles() {
    let files = src_files(&[
        ("rust/src/util/fixture.rs", "use crate::comm::Msg;\n"),
        ("rust/src/comm/fixture.rs", "use crate::util::json;\n"),
    ]);
    let mut findings: Vec<Finding> = Vec::new();
    layering(&parse_all(&files), &mut findings);
    assert!(
        findings
            .iter()
            .any(|f| f.rule == "layering" && f.msg.contains("`util` (layer 0) → `comm` (layer 2)")),
        "{findings:?}"
    );
    assert!(
        findings.iter().any(|f| f.msg.contains("module dependency cycle")),
        "util → comm → util is a cycle: {findings:?}"
    );

    let files = src_files(&[("rust/src/widgets/fixture.rs", "use crate::util::json;\n")]);
    let mut findings = Vec::new();
    layering(&parse_all(&files), &mut findings);
    assert!(
        findings.iter().any(|f| f.rule == "layering" && f.msg.contains("not in the declared DAG")),
        "unregistered module is rejected: {findings:?}"
    );

    assert_eq!(module_of("rust/src/comm/codec/mod.rs"), Some("comm"));
    assert_eq!(module_of("rust/src/lib.rs"), Some("lib"));
    assert_eq!(module_of("rust/tests/schema_gate.rs"), None, "tests are outside the DAG");
    assert!(LAYERS.iter().any(|&(m, l)| m == "util" && l == 0), "util is the bottom layer");
}

#[test]
fn dead_pub_gate_wants_a_cross_module_reference() {
    let orphan = ("rust/src/util/fixture.rs", "pub fn widget_helper() -> u32 { 7 }\n");
    let mut findings = Vec::new();
    dead_pubs(&parse_all(&src_files(&[orphan])), &mut findings);
    assert!(
        findings.iter().any(|f| f.rule == "dead-pub" && f.msg.contains("widget_helper")),
        "{findings:?}"
    );

    let caller_src = "fn call() -> u32 { crate::util::fixture::widget_helper() }\n";
    let caller = ("rust/src/comm/fixture.rs", caller_src);
    let mut findings = Vec::new();
    dead_pubs(&parse_all(&src_files(&[orphan, caller])), &mut findings);
    assert!(findings.is_empty(), "a reference from another module clears it: {findings:?}");
}

#[test]
fn schema_lock_renders_and_parses_golden_fixture() {
    let schema = Schema {
        sections: vec![
            Section {
                header: "enum Msg @ rust/src/comm/transport.rs".to_string(),
                entries: vec![
                    "Dense { w: Vec<f32> }".to_string(),
                    "Sparse(SparseUpdate)".to_string(),
                ],
            },
            Section {
                header: "tags checkpoint @ rust/src/coordinator/checkpoint.rs".to_string(),
                entries: vec!["STATE_TAG_EF = 1".to_string(), "STATE_TAG_RAND = 2".to_string()],
            },
        ],
    };
    let text = render(&schema, 3);
    assert!(text.starts_with('#'), "lock leads with the comment header");
    assert!(text.contains("\nversion = 3\n"));
    assert!(text.contains(
        "\n[enum Msg @ rust/src/comm/transport.rs]\nDense { w: Vec<f32> }\nSparse(SparseUpdate)\n"
    ));
    let (v, parsed) = parse_lock(&text).expect("canonical text parses");
    assert_eq!(v, 3);
    assert_eq!(parsed, schema);
    assert_eq!(render(&parsed, 3), text, "render∘parse is the identity");
    assert!(parse_lock("STATE_TAG_EF = 1\n").is_none(), "entry before any section header");
}

#[test]
fn tag_renumbering_is_rejected_outright() {
    let lock = Schema {
        sections: vec![Section {
            header: "tags checkpoint @ rust/src/coordinator/checkpoint.rs".to_string(),
            entries: vec!["STATE_TAG_EF = 1".to_string(), "STATE_TAG_RAND = 2".to_string()],
        }],
    };
    // seeded violation: the two tags swap values
    let mut cur = lock.clone();
    cur.sections[0].entries =
        vec!["STATE_TAG_EF = 2".to_string(), "STATE_TAG_RAND = 1".to_string()];
    let mut findings = Vec::new();
    compare(&lock, &cur, &mut findings);
    assert!(
        findings
            .iter()
            .any(|f| f.rule == "schema-tag-reuse" && f.msg.contains("STATE_TAG_EF")),
        "renumber names the tag: {findings:?}"
    );

    // a new variant is plain drift, named and actionable
    let mut cur2 = lock.clone();
    cur2.sections[0].entries.push("STATE_TAG_NEW = 3".to_string());
    let mut f2 = Vec::new();
    compare(&lock, &cur2, &mut f2);
    assert!(
        f2.iter()
            .any(|f| f.rule == "schema-drift"
                && f.msg.contains("STATE_TAG_NEW")
                && f.msg.contains("added")),
        "{f2:?}"
    );

    let mut f3 = Vec::new();
    compare(&lock, &lock.clone(), &mut f3);
    assert!(f3.is_empty(), "identical schemas compare clean");
}

#[test]
fn tree_schema_extraction_is_deterministic() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let files = read_tree(root).expect("tree walk");
    let parsed = parse_all(&files);
    let (cur1, f1) = current(&parsed);
    assert!(f1.is_empty(), "all schema source items are present: {f1:?}");
    let (cur2, _) = current(&parse_all(&read_tree(root).expect("tree walk")));
    assert_eq!(render(&cur1, 1), render(&cur2, 1), "same tree → byte-identical lock");
    assert!(cur1.sections.iter().any(|s| s.header.starts_with("enum Msg ")));
}

#[test]
fn missing_lockfile_is_a_finding() {
    let dir = std::env::temp_dir().join(format!("regtopk-schema-gate-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let mut findings = Vec::new();
    check_tree(&dir, &parse_all(&[]), &mut findings);
    assert!(
        findings.iter().any(|f| f.rule == "schema-drift" && f.msg.contains("SCHEMA.lock missing")),
        "{findings:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn full_tree_gate_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report: TreeReport = analyze_tree_full(root).expect("tree walk");
    assert!(report.files_scanned > 50, "scanned {} files", report.files_scanned);
    let failing: Vec<&Finding> = report.failing().collect();
    assert!(failing.is_empty(), "analyzer findings on the repo tree: {failing:?}");

    assert_eq!(RULES.len(), 14);
    let new_rules = [
        "wildcard",
        "layering",
        "dead-pub",
        "schema-drift",
        "schema-tag-reuse",
        "schema-doc",
        "net-outside-transport",
        "bit-kernels-outside-kernels",
    ];
    for rule in new_rules {
        assert!(RULES.contains(&rule), "missing rule id {rule}");
    }
    for path in UNSAFE_ALLOWLIST {
        assert!(path.starts_with("rust/src/"), "allowlist entries are src paths: {path}");
    }
}

#[test]
fn kind_variant_shim_reads_the_enum() {
    let src = "pub enum SparsifierKind {\n    Dense,\n    TopK { k: usize },\n}\n";
    assert_eq!(parse_kind_variants(src), ["Dense", "TopK"]);
}
