//! Bit-identity contract of the chunked kernel layer (PR 10
//! tentpole): every kernel in `util::kernels` against its scalar
//! referee, at sizes 0 / 1 / LANES±1 / large, under adversarial
//! values (NaN, ±inf, -0.0, denormals, RNE ties), plus the two
//! consumer pins — the sharded select engine against the sort oracle
//! over kernel-shaped inputs, and the half-width converts against
//! exhaustive 16-bit code sweeps.
//!
//! "Matches" here always means bit-for-bit (`to_bits` equality), not
//! float `==`: the kernels are only allowed to reorder work across
//! independent elements, never to change a single element's result.

use regtopk::sparse::engine::SelectEngine;
use regtopk::sparse::topk::select_topk_sort;
use regtopk::util::check;
use regtopk::util::kernels::{
    abs_hist, abs_hist_ref, bf16_to_f32, bf16_to_f32_slice, bf16_to_f32_slice_ref,
    boundary_collect, boundary_collect_ref, f16_to_f32, f16_to_f32_slice, f16_to_f32_slice_ref,
    f32_to_bf16, f32_to_bf16_codes, f32_to_bf16_codes_ref, f32_to_f16, f32_to_f16_codes,
    f32_to_f16_codes_ref, fill_abs_hist, fill_abs_hist_ref, hist_bin_edge, mag_bits, pack_fixed,
    pack_fixed_ref, scale_into, scale_into_ref, scatter_add, scatter_add_ref, scatter_assign,
    scatter_assign_ref, unpack_fixed, unpack_fixed_ref, FUSE_BLOCK, LANES,
};
use regtopk::util::rng::Rng;

/// Tail-alignment sweep: empty, single, one short of a lane block, an
/// exact block, one over, a few blocks plus tail, and large enough to
/// span multiple [`FUSE_BLOCK`]s in the fused fill path.
const SIZES: [usize; 7] = [0, 1, LANES - 1, LANES, LANES + 1, 3 * LANES + 5, 2 * FUSE_BLOCK + 37];

/// The values most likely to expose a shortcut in a "vectorized"
/// rewrite: NaN, both infinities, both zeros, denormals, and the
/// exact f16 overflow/tie neighborhood.
const SPECIALS: [f32; 12] = [
    f32::NAN,
    f32::INFINITY,
    f32::NEG_INFINITY,
    -0.0,
    0.0,
    1.0e-42, // f32 denormal
    -1.0e-40,
    f32::MAX,
    -f32::MAX,
    65504.0, // max finite f16
    65520.0, // f16 RNE tie up to inf
    65519.9, // just below the tie
];

fn bits_of(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

/// Random vector with the [`SPECIALS`] spliced in at random slots.
fn special_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = check::arb_vec(rng, n);
    if n > 0 {
        for &s in &SPECIALS {
            let i = rng.below(n);
            v[i] = s;
        }
    }
    v
}

#[test]
fn abs_hist_matches_referee_under_special_values() {
    check::forall("abs_hist_vs_ref", |rng, case| {
        let n = SIZES[case % SIZES.len()];
        let x = special_vec(rng, n);
        let (mut a, mut b) = ([0u32; 256], [0u32; 256]);
        abs_hist(&x, &mut a);
        abs_hist_ref(&x, &mut b);
        assert_eq!(a, b, "n={n}");
        assert_eq!(a.iter().sum::<u32>() as usize, n, "every element lands in a bin");
    });
}

#[test]
fn hist_bin_edges_bound_their_bins() {
    for b in 1..127 {
        assert!(hist_bin_edge(b) > hist_bin_edge(b - 1), "edges are strictly increasing");
    }
    assert_eq!(hist_bin_edge(127), f32::INFINITY);
    assert_eq!(hist_bin_edge(255), f32::INFINITY);
    let mut rng = Rng::seed_from(7);
    let mut vals = SPECIALS.to_vec();
    vals.extend(check::arb_vec(&mut rng, 2000));
    for v in vals {
        let b = (mag_bits(v) >> 24) as usize;
        if v.is_finite() && b < 127 {
            assert!(v.abs() < hist_bin_edge(b), "v={v} bin={b}");
            if b > 0 {
                assert!(v.abs() >= hist_bin_edge(b - 1), "v={v} bin={b}");
            }
        }
    }
}

#[test]
fn fused_fill_hist_matches_unfused_referee() {
    // position-pure fill: element lo+i depends only on lo+i, so the
    // FUSE_BLOCK-grained chunked pass must be invisible
    let fill = |lo: usize, block: &mut [f32]| {
        for (j, slot) in block.iter_mut().enumerate() {
            let i = (lo + j) as f32;
            *slot = (i - 5000.0) * 0.37 + if (lo + j) % 97 == 0 { 1.0e-41 } else { 0.0 };
        }
    };
    for n in SIZES {
        let (mut d1, mut d2) = (vec![0.0f32; n], vec![0.0f32; n]);
        let (mut h1, mut h2) = ([0u32; 256], [0u32; 256]);
        fill_abs_hist(3, &mut d1, &mut h1, fill);
        fill_abs_hist_ref(3, &mut d2, &mut h2, fill);
        assert_eq!(bits_of(&d1), bits_of(&d2), "n={n}: fused buffer is bit-identical");
        assert_eq!(h1, h2, "n={n}");
    }
}

#[test]
fn boundary_collect_matches_referee() {
    check::forall("boundary_collect_vs_ref", |rng, case| {
        let n = SIZES[case % SIZES.len()];
        let x = special_vec(rng, n);
        // boundary buckets: extremes plus one actually present in x
        let present =
            x.first().map(|&v| (mag_bits(v) >> 24) as usize).unwrap_or(0);
        for b in [0usize, present, 127, 255] {
            let hi_floor = ((b as u64) + 1) << 24;
            let base = 1000 * case as u32;
            let (mut w1, mut ci1, mut cv1) = (Vec::new(), Vec::new(), Vec::new());
            let (mut w2, mut ci2, mut cv2) = (Vec::new(), Vec::new(), Vec::new());
            boundary_collect(base, &x, b, hi_floor, &mut w1, &mut ci1, &mut cv1);
            boundary_collect_ref(base, &x, b, hi_floor, &mut w2, &mut ci2, &mut cv2);
            assert_eq!(w1, w2, "winners n={n} b={b}");
            assert_eq!(ci1, ci2, "cand idx n={n} b={b}");
            assert_eq!(bits_of(&cv1), bits_of(&cv2), "cand val n={n} b={b}");
            assert!(w1.windows(2).all(|p| p[0] < p[1]), "winners ascend");
            assert!(ci1.windows(2).all(|p| p[0] < p[1]), "candidates ascend");
        }
    });
}

#[test]
fn scatter_and_scale_kernels_match_referees() {
    check::forall("scatter_vs_ref", |rng, case| {
        let n = SIZES[case % SIZES.len()];
        let dim = (4 * n).max(8);
        let val = special_vec(rng, n);
        // duplicate-heavy indices: entry order must decide the result
        let idx: Vec<u32> = (0..n).map(|_| rng.below(dim / 2) as u32).collect();
        let base = special_vec(rng, dim);
        for c in [1.0f32, -0.25, 0.0, -0.0] {
            let (mut o1, mut o2) = (base.clone(), base.clone());
            scatter_add(&mut o1, &idx, &val, c);
            scatter_add_ref(&mut o2, &idx, &val, c);
            assert_eq!(bits_of(&o1), bits_of(&o2), "scatter_add n={n} c={c}");

            let (mut d1, mut d2) = (base.clone(), base.clone());
            scale_into(&mut d1, &base, c);
            scale_into_ref(&mut d2, &base, c);
            assert_eq!(bits_of(&d1), bits_of(&d2), "scale_into n={n} c={c}");
        }
        let (mut o1, mut o2) = (base.clone(), base.clone());
        scatter_assign(&mut o1, &idx, &val);
        scatter_assign_ref(&mut o2, &idx, &val);
        assert_eq!(bits_of(&o1), bits_of(&o2), "scatter_assign n={n}");
    });
}

#[test]
fn pack_unpack_matches_referee_at_every_width() {
    check::forall("pack_fixed_vs_ref", |rng, case| {
        let n = SIZES[case % SIZES.len()].min(4096);
        let bits = case % 32 + 1;
        let mask: u64 = if bits == 32 { u32::MAX as u64 } else { (1u64 << bits) - 1 };
        let codes: Vec<u32> =
            (0..n).map(|_| (rng.next_u64() & mask) as u32).collect();
        let (mut w1, mut w2) = (Vec::new(), Vec::new());
        pack_fixed(&codes, bits, &mut w1);
        pack_fixed_ref(&codes, bits, &mut w2);
        assert_eq!(w1, w2, "n={n} bits={bits}");
        assert_eq!(w1.len(), (n * bits).div_ceil(32), "exact word count");

        let (mut o1, mut o2) = (Vec::new(), Vec::new());
        unpack_fixed(&w1, bits, n, &mut o1);
        unpack_fixed_ref(&w1, bits, n, &mut o2);
        assert_eq!(o1, codes, "roundtrip n={n} bits={bits}");
        assert_eq!(o2, codes, "referee roundtrip n={n} bits={bits}");

        // trailing bits of the last word are zero (frame bytes beyond
        // the payload are deterministic, not residual garbage)
        if let Some(&last) = w1.last() {
            let used = n * bits - (w1.len() - 1) * 32;
            if used < 32 {
                assert_eq!(last >> used, 0, "n={n} bits={bits}: tail is zeroed");
            }
        }
    });
}

#[test]
fn half_width_slice_converts_match_referees() {
    check::forall("half_codes_vs_ref", |rng, case| {
        let n = SIZES[case % SIZES.len()];
        let x = special_vec(rng, n);
        let (mut c1, mut c2) = (Vec::new(), Vec::new());
        f32_to_bf16_codes(&x, &mut c1);
        f32_to_bf16_codes_ref(&x, &mut c2);
        assert_eq!(c1, c2, "bf16 encode n={n}");
        let (mut d1, mut d2) = (Vec::new(), Vec::new());
        bf16_to_f32_slice(&c1, &mut d1);
        bf16_to_f32_slice_ref(&c1, &mut d2);
        assert_eq!(bits_of(&d1), bits_of(&d2), "bf16 decode n={n}");

        f32_to_f16_codes(&x, &mut c1);
        f32_to_f16_codes_ref(&x, &mut c2);
        assert_eq!(c1, c2, "f16 encode n={n}");
        f16_to_f32_slice(&c1, &mut d1);
        f16_to_f32_slice_ref(&c1, &mut d2);
        assert_eq!(bits_of(&d1), bits_of(&d2), "f16 decode n={n}");
        assert!(c1.iter().all(|&c| c <= u16::MAX as u32), "codes are true 16-bit words");
    });
}

/// Exhaustive 16-bit sweep: widening then re-narrowing every f16 code
/// is the identity (half values are exactly representable in f32), so
/// a half-width wire bucket decodes losslessly and re-encodes to the
/// same bytes.  Signaling NaNs are exempt — the encoder quiets them.
#[test]
fn f16_widen_narrow_is_identity_on_all_codes() {
    for u in 0..=u16::MAX {
        let exp = (u >> 10) & 0x1F;
        let man = u & 0x03FF;
        let signaling_nan = exp == 0x1F && man != 0 && man & 0x200 == 0;
        if signaling_nan {
            assert!(f16_to_f32(u).is_nan());
            continue;
        }
        assert_eq!(f32_to_f16(f16_to_f32(u)), u, "code {u:#06x}");
    }
}

/// Same sweep for bf16: every non-signaling-NaN 16-bit pattern
/// survives widen → narrow exactly.
#[test]
fn bf16_widen_narrow_is_identity_on_all_codes() {
    for u in 0..=u16::MAX {
        let exp = (u >> 7) & 0xFF;
        let man = u & 0x7F;
        let signaling_nan = exp == 0xFF && man != 0 && man & 0x40 == 0;
        if signaling_nan {
            assert!(bf16_to_f32(u).is_nan());
            continue;
        }
        assert_eq!(f32_to_bf16(bf16_to_f32(u)), u, "code {u:#06x}");
    }
}

/// Round-to-nearest-even tie pins, mid-mantissa (the golden unit
/// tests cover the range ends; these are the interior ties).
#[test]
fn half_width_rounding_is_ties_to_even() {
    // f32 1.00390625 sits exactly between bf16 codes 0x3F80 and
    // 0x3F81 — RNE picks the even one
    assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80);
    assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_8000)), 0x3F82);
    // f16: low 13 bits exactly at the halfway point
    assert_eq!(f32_to_f16(f32::from_bits(0x3F80_1000)), 0x3C00, "tie to even (down)");
    assert_eq!(f32_to_f16(f32::from_bits(0x3F80_3000)), 0x3C02, "tie to even (up)");
    // one past the tie always rounds up
    assert_eq!(f32_to_f16(f32::from_bits(0x3F80_1001)), 0x3C01);
    // subnormal tie: 1.5 * 2^-24 is between codes 0x0001 and 0x0002
    assert_eq!(f32_to_f16(1.5 * 2.0f32.powi(-24)), 0x0002, "subnormal tie to even");
    assert_eq!(f32_to_f16(0.5 * 2.0f32.powi(-24)), 0x0000, "half-ulp tie to even zero");
}

/// Consumer pin: the kernelized sharded engine still matches the sort
/// oracle bit-for-bit on kernel-adversarial inputs (NaN, ±inf, -0.0,
/// denormals), for shard counts that leave misaligned tails.
#[test]
fn kernelized_engine_matches_sort_oracle_on_special_values() {
    check::forall("engine_vs_sort_special", |rng, case| {
        let n = [1usize, LANES, 300, 4097][case % 4];
        let x = special_vec(rng, n);
        for k in [1usize, n / 3 + 1, n] {
            let want = select_topk_sort(&x, k);
            for shards in [1usize, 3, 8] {
                let mut eng = SelectEngine::new(shards);
                let mut got = Vec::new();
                eng.select_into(&x, k, &mut got);
                assert_eq!(got, want, "n={n} k={k} shards={shards}");
            }
        }
    });
}
