//! DESIGN.md invariant 6: same config => bit-identical results, across
//! both drivers, after state reuse, and for EVERY sparsifier family —
//! the analyzer's `kind-matrix` rule fails the build if a family is
//! added without appearing here.

use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::{fig1, fig2};
use regtopk::sparsify::SparsifierKind;

/// Every sparsifier family on a dim-16 testbed (k = dim/4).
fn all_kinds(dim: usize) -> Vec<SparsifierKind> {
    let k = (dim / 4).max(1);
    vec![
        SparsifierKind::Dense,
        SparsifierKind::TopK { k },
        SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
        SparsifierKind::RandK { k, seed: 5 },
        SparsifierKind::Threshold { tau: 0.5 },
        SparsifierKind::GlobalTopK { k },
        SparsifierKind::Dgc { k, momentum: 0.9, clip: 0.0 },
        SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 2 * k },
    ]
}

#[test]
fn fig2_runs_are_bit_identical() {
    let params = LinearParams { workers: 5, rows_per_worker: 100, dim: 20, ..LinearParams::fig2() };
    let a = generate(params, 9);
    let b = generate(params, 9);
    let kind = SparsifierKind::RegTopK { k: 10, mu: 0.5, q: 1.0 };
    let la = fig2::run_curve(&a, kind.clone(), "a", 100, 0.02);
    let lb = fig2::run_curve(&b, kind, "b", 100, 0.02);
    for (ra, rb) in la.records().iter().zip(lb.records()) {
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
        assert_eq!(ra.opt_gap.to_bits(), rb.opt_gap.to_bits());
        assert_eq!(ra.upload_bytes, rb.upload_bytes);
    }
}

#[test]
fn threaded_and_deterministic_drivers_agree_bitwise() {
    let params = LinearParams { workers: 4, rows_per_worker: 80, dim: 16, ..LinearParams::fig2() };
    let problem = generate(params, 4);
    for kind in all_kinds(16) {
        // the genie side-channel (global top-k oracle) only exists on
        // the deterministic driver; run_threaded asserts it out
        if matches!(kind, SparsifierKind::GlobalTopK { .. }) {
            continue;
        }
        let mut det = fig2::trainer_for(&problem, kind.clone(), 0.02);
        for _ in 0..50 {
            det.round();
        }
        let mut thr = fig2::trainer_for(&problem, kind.clone(), 0.02);
        thr.run_threaded(50);
        assert_eq!(det.server.w, thr.server.w, "{kind:?}");
    }
}

#[test]
fn deterministic_reruns_bit_identical_for_all_families() {
    // GlobalTopK included: reruns of the deterministic driver must be
    // bit-identical for every family, genie-dependent or not
    let params = LinearParams { workers: 4, rows_per_worker: 80, dim: 16, ..LinearParams::fig2() };
    let problem = generate(params, 11);
    for kind in all_kinds(16) {
        let mut a = fig2::trainer_for(&problem, kind.clone(), 0.02);
        let mut b = fig2::trainer_for(&problem, kind.clone(), 0.02);
        for _ in 0..30 {
            a.round();
            b.round();
        }
        assert_eq!(a.server.w, b.server.w, "{kind:?}");
        for (wa, wb) in a.server.w.iter().zip(&b.server.w) {
            assert_eq!(wa.to_bits(), wb.to_bits(), "{kind:?}");
        }
    }
}

#[test]
fn csv_output_is_byte_identical_across_runs() {
    let a = fig1::run(30, 0.5, 1.0);
    let b = fig1::run(30, 0.5, 1.0);
    for (la, lb) in a.iter().zip(&b) {
        assert_eq!(la.to_csv(), lb.to_csv());
        assert_eq!(la.to_json().dump(), lb.to_json().dump());
    }
}
