//! Contract of the pluggable wire-codec stack (ISSUE 5 tentpole):
//!
//! 1. **Equivalence net** — with `idx`/`levels`/`bits` unset (in any
//!    spelling of the defaults) the grouped trainer is bit-identical
//!    to the pre-codec PR 4 tree for ALL EIGHT sparsifier families,
//!    flat and grouped: same trajectories, same checkpoints, same
//!    ledger byte totals, and the per-round bytes match the PR 4
//!    formula `ceil(nnz * (32 + ceil(log2 dim)) / 8)` re-derived by
//!    hand;
//! 2. **Losslessness** — Golomb–Rice index payloads decode to exactly
//!    the bucket's index list and value payloads decode bit-exact to
//!    the bucket's values, for every codec pair at sizes
//!    0/1/tiny/large;
//! 3. **Accounting** — ledger bytes equal the codec payloads' own wire
//!    accounting for every `idx` x `levels` combination, and an
//!    `idx=rice` run transmits the SAME values as the packed baseline
//!    (an index codec cannot touch the trajectory) for fewer bytes;
//! 4. **Auto width** — `bits=auto:LO..HI` stays inside its range and
//!    its trajectory is reproducible from a fresh build (pure function
//!    of the data), with resume covered in `rust/tests/resume.rs`.

use regtopk::comm::codec::{
    decode_header, decode_msg, encode_msg, index_bits, FrameStats, IndexCodec, LevelKind,
    QuantPayload, RicePayload, ValueCodec, WireCost, FRAME_HEADER_BYTES, FRAME_MAGIC,
    WIRE_VERSION,
};
use regtopk::config::TrainConfig;
use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::fig2;
use regtopk::grad::{GradLayout, GradView};
use regtopk::comm::{Msg, SparseUpdate};
use regtopk::sparse::SparseVec;
use regtopk::sparsify::{
    BudgetPolicy, LayerwiseSparsifier, PolicyTable, RoundCtx, Sparsifier, SparsifierKind,
};
use regtopk::util::check;
use regtopk::util::kernels::{hist_bin_edge, mag_bits};
use regtopk::util::rng::Rng;

fn all_kinds(dim: usize) -> Vec<SparsifierKind> {
    let k = (dim / 4).max(1);
    vec![
        SparsifierKind::Dense,
        SparsifierKind::TopK { k },
        SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
        SparsifierKind::RandK { k, seed: 5 },
        SparsifierKind::Threshold { tau: 0.5 },
        SparsifierKind::GlobalTopK { k },
        SparsifierKind::Dgc { k, momentum: 0.9, clip: 0.0 },
        SparsifierKind::AdaK { ratio: 1.0, k_min: 1, k_max: 2 * k },
    ]
}

fn grouped_layout() -> GradLayout {
    GradLayout::from_sizes([("conv.w".to_string(), 16), ("conv.b".to_string(), 8)])
}

/// Equivalence net: every spelling of "default codecs" — no policy, an
/// inherit-all rule, explicit `idx=packed`, explicit
/// `bits=4,levels=uniform` vs plain `bits=4` — keeps the grouped
/// trainer bit-identical across spellings for every family, and the
/// codec-unset byte stream matches the PR 4 formula by hand.
#[test]
fn codec_unset_is_bit_identical_for_all_families() {
    let params =
        LinearParams { workers: 3, rows_per_worker: 60, dim: 24, ..LinearParams::fig2() };
    let problem = generate(params, 7);
    for kind in all_kinds(24) {
        let base = TrainConfig {
            workers: 3,
            eta: 0.03,
            sparsifier: kind.clone(),
            eval_every: 0,
            groups: Some(grouped_layout()),
            budget: Some(BudgetPolicy::Global { k: 6 }),
            ..TrainConfig::default()
        };
        // three spellings of "no codec"
        let mut none = base.clone();
        none.policy = None;
        let mut inherit = base.clone();
        inherit.policy = Some(PolicyTable::parse("*=").unwrap());
        let mut packed = base.clone();
        packed.policy = Some(PolicyTable::parse("*=:idx=packed").unwrap());
        let mut tr_none = fig2::trainer_from_config(&none, &problem);
        let mut tr_inherit = fig2::trainer_from_config(&inherit, &problem);
        let mut tr_packed = fig2::trainer_from_config(&packed, &problem);
        for _ in 0..12 {
            tr_none.round();
            tr_inherit.round();
            tr_packed.round();
        }
        assert_eq!(tr_none.server.w, tr_inherit.server.w, "{kind:?} inherit-rule");
        assert_eq!(tr_none.server.w, tr_packed.server.w, "{kind:?} idx=packed");
        for (a, b) in tr_none.ledger.rounds().iter().zip(tr_packed.ledger.rounds()) {
            assert_eq!(a.upload_bytes, b.upload_bytes, "{kind:?} round {}", a.round);
        }
        assert_eq!(
            tr_none.ledger.group_upload_totals(),
            tr_packed.ledger.group_upload_totals(),
            "{kind:?}"
        );
        // the same for the two spellings of the default value codec
        let mut u4 = base.clone();
        u4.policy = Some(PolicyTable::parse("*=:bits=4").unwrap());
        let mut u4x = base.clone();
        u4x.policy = Some(PolicyTable::parse("*=:bits=4,levels=uniform").unwrap());
        let mut tr_u4 = fig2::trainer_from_config(&u4, &problem);
        let mut tr_u4x = fig2::trainer_from_config(&u4x, &problem);
        for _ in 0..12 {
            tr_u4.round();
            tr_u4x.round();
        }
        assert_eq!(tr_u4.server.w, tr_u4x.server.w, "{kind:?} levels=uniform");
        assert_eq!(
            tr_u4.ledger.group_upload_totals(),
            tr_u4x.ledger.group_upload_totals(),
            "{kind:?}"
        );
    }
}

/// The codec-unset byte stream is the PR 4 formula, re-derived by hand
/// from the updates themselves: per bucket,
/// `ceil(nnz * (32 + ceil(log2 dim)) / 8)`.
#[test]
fn codec_unset_bytes_match_the_pr4_formula_by_hand() {
    let layout = grouped_layout();
    let mut lw = LayerwiseSparsifier::new(
        &SparsifierKind::TopK { k: 6 },
        layout.clone(),
        &BudgetPolicy::Global { k: 6 },
        0,
    );
    let mut gagg = vec![0.0f32; 24];
    let mut up = SparseUpdate::empty();
    let wc = WireCost::paper();
    for t in 0..6 {
        let g: Vec<f32> = (0..24).map(|i| ((i * 5 + t * 7) % 9) as f32 - 4.0).collect();
        let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.5, genie_acc: None };
        let view = GradView::new(&layout, &g);
        lw.step_group_into(&view, &ctx, &mut up);
        let by_hand: usize = (0..up.num_buckets())
            .map(|gi| {
                let b = up.bucket(gi);
                (b.nnz() * (32 + index_bits(b.dim()))).div_ceil(8)
            })
            .sum();
        assert_eq!(wc.update(&up), by_hand, "t={t}");
        gagg = up.flatten().to_dense();
    }
}

/// Losslessness across the whole codec matrix on random buckets at
/// boundary sizes: the index payload decodes to the exact index list
/// and the value payload decodes bit-exact to the bucket values.
#[test]
fn codec_pairs_roundtrip_random_buckets() {
    check::forall("codec_pair_roundtrip", |rng, _| {
        // sizes 0 / 1 / tiny / large
        let n = [0usize, 1, 1 + rng.below(7), 50 + rng.below(200)][rng.below(4)];
        let dim = (n.max(1) * (1 + rng.below(2000))).max(2);
        let mut idx = rng.sample_indices(dim, n);
        idx.sort_unstable();
        let idx: Vec<u32> = idx.into_iter().map(|i| i as u32).collect();
        let vals: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let orig = SparseVec::new(dim, idx.clone(), vals.clone());
        for levels in [None, Some(LevelKind::Uniform), Some(LevelKind::Nuq)] {
            for idx_codec in [IndexCodec::Packed, IndexCodec::Raw, IndexCodec::Rice] {
                let mut bucket = orig.clone();
                let mut payload = QuantPayload::default();
                // value axis
                if let Some(lv) = levels {
                    let bits = 2 + rng.below(15);
                    let (mut residual, mut codes) = (Vec::new(), Vec::new());
                    ValueCodec { bits, levels: lv }.encode_bucket(
                        &mut bucket,
                        rng,
                        &mut payload,
                        &mut residual,
                        &mut codes,
                    );
                    for i in 0..n {
                        assert_eq!(
                            payload.decode_value(i),
                            bucket.values()[i],
                            "{lv:?} i={i}"
                        );
                        assert_eq!(residual[i], vals[i] - bucket.values()[i], "{lv:?} i={i}");
                    }
                }
                // index axis
                if idx_codec == IndexCodec::Rice {
                    let mut rp = RicePayload::default();
                    rp.encode_into(bucket.indices());
                    assert_eq!(rp.decode(), idx, "rice dim={dim} n={n}");
                }
            }
        }
    });
}

/// Accounting contract across the matrix, end to end through a real
/// sparsifier stack: ledger bytes equal the payloads' own accounting
/// for every `idx` x `levels` pair.
#[test]
fn ledger_bytes_equal_codec_accounting_for_every_pair() {
    use regtopk::comm::{CostModel, Ledger};
    let layout = GradLayout::from_sizes([("a".to_string(), 256), ("b".to_string(), 256)]);
    let specs = [
        "*=:idx=raw",
        "*=:idx=rice",
        "*=:bits=5",
        "*=:bits=5,idx=rice",
        "*=:bits=5,levels=nuq",
        "*=:bits=5,idx=raw,levels=nuq",
        "a=:bits=4,idx=rice;b=:idx=raw",
        // half-width wire values (PR 10): fixed 16-bit, scale-free
        "*=:levels=fp16",
        "*=:levels=bf16,idx=rice",
        "a=:levels=fp16;b=:bits=5,levels=nuq",
    ];
    for spec in specs {
        let table = PolicyTable::parse(spec).unwrap();
        let mut lw = LayerwiseSparsifier::with_policies(
            &SparsifierKind::TopK { k: 24 },
            layout.clone(),
            &BudgetPolicy::Global { k: 24 },
            &table,
            0,
        );
        let cost = CostModel::default();
        let mut ledger = Ledger::new(cost);
        ledger.set_layout(&layout);
        let gagg = vec![0.0f32; 512];
        let mut up = SparseUpdate::empty();
        let mut want = [0usize; 2];
        for t in 0..4 {
            let g: Vec<f32> =
                (0..512).map(|i| ((i * 5 + t * 3) % 13) as f32 - 6.0).collect();
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 1.0, genie_acc: None };
            let view = GradView::new(&layout, &g);
            lw.step_group_into(&view, &ctx, &mut up);
            ledger.record_update(&up);
            ledger.close_round(t, 512, 1);
            for gi in 0..2 {
                let b = up.bucket(gi);
                // re-derive the charge from the payloads alone
                let vbytes = match (up.quant(gi), up.rice(gi).is_some(), up.raw_index(gi)) {
                    (Some(q), true, _) => q.wire_bytes(0),
                    (Some(q), false, true) => q.wire_bytes(32),
                    (Some(q), false, false) => q.wire_bytes(index_bits(b.dim())),
                    (None, true, _) => (b.nnz() * 32).div_ceil(8),
                    (None, false, true) => (b.nnz() * (32 + 32)).div_ceil(8),
                    (None, false, false) => {
                        (b.nnz() * (32 + index_bits(b.dim()))).div_ceil(8)
                    }
                };
                want[gi] += vbytes + up.rice(gi).map_or(0, RicePayload::wire_bytes);
                // rice payloads always decode to the bucket's indices
                if let Some(rp) = up.rice(gi) {
                    assert_eq!(rp.decode(), b.indices(), "{spec} g={gi}");
                }
            }
        }
        let totals = ledger.group_upload_totals();
        for gi in 0..2 {
            assert_eq!(totals[gi].1, want[gi], "{spec} group {gi}");
        }
    }
}

/// An index codec cannot touch the trajectory: `idx=rice` transmits
/// the same values as the packed baseline — the model walks the same
/// path — while the ledger reports fewer bytes.
#[test]
fn rice_run_matches_baseline_trajectory_with_fewer_bytes() {
    let params =
        LinearParams { workers: 3, rows_per_worker: 60, dim: 24, ..LinearParams::fig2() };
    let problem = generate(params, 9);
    let base = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 8, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(GradLayout::single(24)),
        budget: Some(BudgetPolicy::Global { k: 8 }),
        ..TrainConfig::default()
    };
    let mut riced = base.clone();
    riced.policy = Some(PolicyTable::parse("*=:idx=rice").unwrap());
    let mut tr_a = fig2::trainer_from_config(&base, &problem);
    let mut tr_b = fig2::trainer_from_config(&riced, &problem);
    for _ in 0..15 {
        tr_a.round();
        tr_b.round();
    }
    assert_eq!(tr_a.server.w, tr_b.server.w, "index codec changed the trajectory");
    let (a, b) = (tr_a.ledger.total_upload_bytes(), tr_b.ledger.total_upload_bytes());
    assert!(b < a, "rice {b} !< packed {a}");
    // the manifest echo surfaces the codec
    let echo = tr_b.config_echo();
    let resolved = echo.get("resolved").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(resolved[0].get("idx").and_then(|j| j.as_str()), Some("rice"));
    assert_eq!(resolved[0].get("levels").and_then(|j| j.as_str()), Some("f32"));
}

/// NUQ value codec end to end: converges in a sane band of the
/// unquantized run at a fraction of the bytes (same contract the
/// uniform codec satisfies in `rust/tests/quantized.rs`).
#[test]
fn nuq_training_converges_with_fewer_bytes() {
    let params =
        LinearParams { workers: 4, rows_per_worker: 100, dim: 40, ..LinearParams::fig2() };
    let problem = generate(params, 11);
    let layout =
        GradLayout::from_sizes([("fc0.w".to_string(), 32), ("fc0.b".to_string(), 8)]);
    let base = TrainConfig {
        workers: 4,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 10, mu: 0.5, q: 1.0 },
        eval_every: 1,
        groups: Some(layout),
        budget: Some(BudgetPolicy::Global { k: 10 }),
        ..TrainConfig::default()
    };
    let mut nuq = base.clone();
    nuq.policy = Some(PolicyTable::parse("*=:bits=5,levels=nuq").unwrap());
    let mut tr_raw = fig2::trainer_from_config(&base, &problem);
    let mut tr_q = fig2::trainer_from_config(&nuq, &problem);
    let log_raw = fig2::run_curve_with(&mut tr_raw, &problem, "raw", 250);
    let log_q = fig2::run_curve_with(&mut tr_q, &problem, "nuq5", 250);
    let gap_raw = log_raw.last().unwrap().opt_gap;
    let gap_q = log_q.last().unwrap().opt_gap;
    assert!(gap_q.is_finite() && gap_q < 6.0 * gap_raw.max(0.05), "{gap_q} vs {gap_raw}");
    let bytes_raw = tr_raw.ledger.total_upload_bytes();
    let bytes_q = tr_q.ledger.total_upload_bytes();
    assert!((bytes_q as f64) < 0.55 * bytes_raw as f64, "nuq {bytes_q} vs raw {bytes_raw}");
}

/// Auto width end to end: the width stays inside the policy range,
/// the run converges, and a fresh build replays the identical
/// trajectory (the steering is a pure function of the data).
#[test]
fn auto_bits_trajectory_is_reproducible_and_in_range() {
    let params =
        LinearParams { workers: 3, rows_per_worker: 60, dim: 24, ..LinearParams::fig2() };
    let problem = generate(params, 13);
    let cfg = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::TopK { k: 6 },
        eval_every: 0,
        groups: Some(grouped_layout()),
        budget: Some(BudgetPolicy::Global { k: 6 }),
        policy: Some(PolicyTable::parse("*=:bits=auto:4..8").unwrap()),
        ..TrainConfig::default()
    };
    let mut tr_a = fig2::trainer_from_config(&cfg, &problem);
    let mut tr_b = fig2::trainer_from_config(&cfg, &problem);
    for _ in 0..20 {
        tr_a.round();
        tr_b.round();
        let bits = tr_a.workers[0].sparsifier.group_value_bits();
        assert!(bits.iter().all(|&b| (4..=8).contains(&b)), "{bits:?}");
    }
    assert_eq!(tr_a.server.w, tr_b.server.w, "auto width must be deterministic");
    assert_eq!(tr_a.ledger.total_upload_bytes(), tr_b.ledger.total_upload_bytes());
    assert!(tr_a.server.w.iter().all(|w| w.is_finite()));
}

/// PR 10 satellite pin: the NUQ scale is fit from the bucket's
/// magnitude histogram — a power-of-two bin edge covering all but at
/// most `n/16` entries — not the outlier-sensitive max; clamped
/// outliers still consume exactly one rounding draw each, so the RNG
/// stream position never depends on the values.
#[test]
fn nuq_scale_is_histogram_fit_not_max() {
    let mut vals = vec![1.0f32; 30];
    vals.extend([1.0e4, -2.0e4]); // 2 outliers == the n/16 budget for n=32
    let orig = vals.clone();
    let mut bucket = SparseVec::new(64, (0..32).collect(), vals);
    let mut rng = Rng::seed_from(21);
    let mut payload = QuantPayload::default();
    let (mut residual, mut codes) = (Vec::new(), Vec::new());
    let vc = ValueCodec { bits: 5, levels: LevelKind::Nuq };
    vc.encode_bucket(&mut bucket, &mut rng, &mut payload, &mut residual, &mut codes);

    // the fitted scale is the power-of-two upper edge of 1.0's
    // histogram bin (2.0), not the 2e4 max a max-fit would pick
    let b = (mag_bits(1.0) >> 24) as usize;
    assert_eq!(payload.scale(), hist_bin_edge(b));
    assert_eq!(payload.scale(), 2.0);
    // payload stays authoritative and the outliers clamp to the top
    // level, their error riding error feedback
    for i in 0..32 {
        assert_eq!(payload.decode_value(i), bucket.values()[i], "i={i}");
        assert_eq!(residual[i], orig[i] - bucket.values()[i], "i={i}");
    }
    assert!(bucket.values()[30].abs() <= payload.scale(), "outlier clamps to the table");
    assert!(residual[31].abs() > 1.0e3, "clamp error is fed back, not dropped");

    // stream-position pin: an outlier-free bucket of the same length
    // consumes the identical RNG span (one draw per entry)
    let mut r2 = Rng::seed_from(21);
    let mut b2 = SparseVec::new(64, (0..32).collect(), vec![1.0f32; 32]);
    vc.encode_bucket(&mut b2, &mut r2, &mut payload, &mut residual, &mut codes);
    assert_eq!(rng.state(), r2.state(), "clamping must not shift the rounding stream");
}

/// PR 10 satellite pin: `levels=fp16|bf16` carries true 16-bit words —
/// deterministic RNE narrowing (no RNG draws), exact widening decode,
/// a scale-free payload, and a charge of exactly 16 bits per value.
#[test]
fn half_width_codec_is_deterministic_and_charges_sixteen_bits() {
    for levels in [LevelKind::Fp16, LevelKind::Bf16] {
        let vals = vec![1.5f32, -0.333333, 6.1e-5, -65504.0, 0.0];
        let orig = vals.clone();
        let mut bucket = SparseVec::new(100, vec![2, 17, 40, 63, 99], vals);
        let mut rng = Rng::seed_from(3);
        let s0 = rng.state();
        let mut payload = QuantPayload::default();
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        let vc = ValueCodec { bits: 16, levels };
        vc.encode_bucket(&mut bucket, &mut rng, &mut payload, &mut residual, &mut codes);

        assert_eq!(rng.state(), s0, "{levels:?}: RNE narrowing draws nothing");
        assert_eq!((payload.bits(), payload.level_kind()), (16, levels));
        assert_eq!(payload.scale(), 0.0, "half payloads are scale-free");
        for i in 0..5 {
            assert_eq!(
                payload.decode_value(i).to_bits(),
                bucket.values()[i].to_bits(),
                "{levels:?} i={i}"
            );
            assert_eq!(residual[i], orig[i] - bucket.values()[i], "{levels:?} i={i}");
        }
        // 1.5 and 0.0 are exactly representable in both half formats
        assert_eq!(bucket.values()[0], 1.5, "{levels:?}");
        assert_eq!(bucket.values()[4], 0.0, "{levels:?}");
        // charged bytes: 16 bits/value + index bits, and NO 4-byte scale
        let ib = index_bits(100);
        assert_eq!(payload.wire_bytes(ib), (5 * (16 + ib)).div_ceil(8), "{levels:?}");
        assert_eq!(
            QuantPayload::bytes_for(5, 4, ib) - 4,
            (5 * (4 + ib)).div_ceil(8),
            "uniform still pays its scale word"
        );
    }
}

/// Half-width end to end: an fp16 uplink walks its own (finite,
/// converging) trajectory at roughly half the value bytes of the raw
/// run, and the manifest echo surfaces the family.
#[test]
fn half_width_training_shrinks_value_bytes() {
    let params =
        LinearParams { workers: 3, rows_per_worker: 60, dim: 24, ..LinearParams::fig2() };
    let problem = generate(params, 15);
    let base = TrainConfig {
        workers: 3,
        eta: 0.03,
        sparsifier: SparsifierKind::RegTopK { k: 8, mu: 0.5, q: 1.0 },
        eval_every: 0,
        groups: Some(GradLayout::single(24)),
        budget: Some(BudgetPolicy::Global { k: 8 }),
        ..TrainConfig::default()
    };
    let mut half = base.clone();
    half.policy = Some(PolicyTable::parse("*=:levels=fp16").unwrap());
    let mut tr_raw = fig2::trainer_from_config(&base, &problem);
    let mut tr_h = fig2::trainer_from_config(&half, &problem);
    for _ in 0..15 {
        tr_raw.round();
        tr_h.round();
    }
    assert!(tr_h.server.w.iter().all(|w| w.is_finite()));
    let (a, b) = (tr_raw.ledger.total_upload_bytes(), tr_h.ledger.total_upload_bytes());
    // per entry: 32+log2(24) bits -> 16+log2(24) bits = 21/37 of the raw charge
    assert!((b as f64) < 0.65 * a as f64, "fp16 {b} !< 0.65 * raw {a}");
    let echo = tr_h.config_echo();
    let resolved = echo.get("resolved").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(resolved[0].get("levels").and_then(|j| j.as_str()), Some("fp16"));
    assert_eq!(resolved[0].get("bits").and_then(|j| j.as_f64()), Some(16.0));
}

/// Golden-bytes fixture for the framed wire format (PR 9): the exact
/// byte image of a known `Msg::Update` is pinned, so any accidental
/// change to the v2 frame layout — header fields, endianness, bucket
/// structure, bit packing — fails here before it ships.  The bytes
/// were derived by hand from docs/WIRE.md §v2.
#[test]
fn framed_update_golden_bytes() {
    #[rustfmt::skip]
    const GOLDEN: [u8; 54] = [
        // header: magic "RTKW", version 2, kind Update, pad, round 3,
        // worker 1, payload len 34
        0x52, 0x54, 0x4B, 0x57, 0x02, 0x00, 0x00, 0x00, 0x03, 0x00,
        0x00, 0x00, 0x01, 0x00, 0x00, 0x00, 0x22, 0x00, 0x00, 0x00,
        // loss 0.5, total_dim 8, num_buckets 1
        0x00, 0x00, 0x00, 0x3F, 0x08, 0x00, 0x00, 0x00, 0x01, 0x00,
        // bucket: offset 0, dim 8, nnz 2, flags 0
        0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x08, 0x00, 0x00, 0x00,
        0x02, 0x00, 0x00, 0x00, 0x00,
        // LSB-first packed (value:32, index:3)*2 = 70 bits -> 9 bytes
        0x00, 0x00, 0x80, 0x3F, 0x01,
        0x00, 0x00, 0x00, 0x36,
    ];
    let up = SparseUpdate::single(SparseVec::new(8, vec![1, 6], vec![1.0, -2.0]));
    let charged = WireCost::paper().update(&up);
    let msg = Msg::Update { worker: 1, round: 3, update: up, loss: 0.5 };
    let (bytes, st) = encode_msg(&msg);
    assert_eq!(bytes[..], GOLDEN[..], "framed byte image drifted");
    assert_eq!(st, FrameStats { bytes: GOLDEN.len(), wire: charged });
    assert_eq!(charged, (2usize * (32 + index_bits(8))).div_ceil(8));
    // header invariants, via the public header decoder
    assert_eq!(&bytes[..4], FRAME_MAGIC);
    let h = decode_header(&bytes[..FRAME_HEADER_BYTES]).expect("header");
    assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), WIRE_VERSION);
    assert_eq!((h.round, h.worker), (3, 1));
    assert_eq!(h.len as usize, GOLDEN.len() - FRAME_HEADER_BYTES);
    // lossless: decode returns the identical message and stats, and
    // re-encoding reproduces the fixture byte-for-byte
    let (back, st2) = decode_msg(&bytes).expect("decode");
    assert_eq!(back, msg);
    assert_eq!(st2, st);
    assert_eq!(encode_msg(&back).0, bytes);
}

/// The packed/raw/rice accounting helpers agree with a brute-force
/// bit count (pinning the exact PR 4 constants one more way).
#[test]
fn wire_cost_formula_pins() {
    let wc = WireCost::paper();
    // the PR 2 pin: J=100, 10 entries -> 49 bytes
    assert_eq!(wc.raw_bucket(10, 100), 49);
    // quantized: 10 entries at 4 bits + 10 index bits + scale = 22
    assert_eq!(QuantPayload::bytes_for(10, 4, 10), 22);
    // a rice bucket charges the measured stream + 1-byte parameter
    let mut rp = RicePayload::default();
    rp.encode_into(&[0, 1, 2, 3]);
    assert_eq!(rp.wire_bytes(), 1 + rp.bit_len().div_ceil(8));
    assert_eq!(rp.bit_len(), 4, "zero gaps cost one terminator bit each");
}
