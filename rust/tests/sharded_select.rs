//! Contract of the sharded sparsification engine (PR 1 tentpole):
//!
//! 1. the fused sharded select matches `select_topk_sort` bit-for-bit —
//!    indices AND tie-breaks — for every shard count;
//! 2. a full RegTop-k trajectory is bit-identical between shards=N and
//!    shards=1 (and the seed serial path), so the shard count is purely
//!    a performance knob;
//! 3. the trainer produces bit-identical models with sharding on.

use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::fig2;
use regtopk::sparse::engine::SelectEngine;
use regtopk::sparse::topk::select_topk_sort;
use regtopk::sparse::SparseVec;
use regtopk::sparsify::{build, RoundCtx, SparsifierKind};
use regtopk::util::check;
use regtopk::util::rng::Rng;

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 8];

/// Property: sharded select == sort oracle for shard counts {1,2,3,8}
/// and k in {1, J/1000, J/8} (plus random k), across random inputs with
/// adversarial values (zeros, duplicates, huge/tiny magnitudes).
#[test]
fn sharded_select_matches_sort_oracle_bit_for_bit() {
    check::forall("sharded_select_vs_sort", |rng, case| {
        // mix of small random lengths and k-regime-relevant sizes
        let n = if case % 3 == 0 { 2048 + rng.below(4096) } else { check::arb_len(rng, 500) };
        let x = check::arb_vec(rng, n);
        let ks = [1usize, (n / 1000).max(1), (n / 8).max(1), rng.below(n + 2)];
        for &k in &ks {
            let want = select_topk_sort(&x, k);
            for shards in SHARD_COUNTS {
                let mut eng = SelectEngine::new(shards);
                let mut got = Vec::new();
                eng.select_into(&x, k, &mut got);
                assert_eq!(got, want, "n={n} k={k} shards={shards}");
            }
        }
    });
}

/// The exact tie-break contract: equal magnitudes (including opposite
/// signs) resolve toward the LOWER index under every shard count, even
/// when the tied plateau spans shard boundaries.
#[test]
fn tie_breaks_survive_shard_boundaries() {
    // 9000 identical magnitudes +-1.0: any k must select 0..k
    let x: Vec<f32> = (0..9000).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    for shards in SHARD_COUNTS {
        let mut eng = SelectEngine::new(shards);
        let mut got = Vec::new();
        for k in [1usize, 9, 4500, 8999] {
            eng.select_into(&x, k, &mut got);
            assert_eq!(got, (0..k as u32).collect::<Vec<_>>(), "k={k} shards={shards}");
        }
    }
}

/// Determinism: a full RegTop-k trajectory (warm-up round + regularized
/// rounds, evolving aggregate feedback) is bit-identical between the
/// serial path, shards=1, and shards=8.
#[test]
fn regtopk_trajectory_bit_identical_across_shard_counts() {
    let dim = 600;
    let k = 13;
    let mut serial = build(&SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 }, dim, 0);
    let mut sh1 = build(&SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 }, dim, 0);
    let mut sh8 = build(&SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 }, dim, 0);
    sh1.set_shards(1); // explicit serial fallback
    sh8.set_shards(8); // engine on, even below the trainer threshold
    let mut rng = Rng::seed_from(123);
    let mut gagg = vec![0.0f32; dim];
    let mut out1 = SparseVec::zeros(dim);
    let mut out8 = SparseVec::zeros(dim);
    for t in 0..12 {
        let g = rng.gaussian_vec(dim, 1.0);
        let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.25, genie_acc: None };
        let want = serial.step(&g, &ctx);
        sh1.step_into(&g, &ctx, &mut out1);
        sh8.step_into(&g, &ctx, &mut out8);
        assert_eq!(want, out1, "t={t} shards=1");
        assert_eq!(want, out8, "t={t} shards=8");
        // feed the aggregate back so Delta is exercised (non-zero mask)
        gagg = want.to_dense();
        for v in gagg.iter_mut() {
            *v *= 0.5;
        }
    }
}

/// Same contract for TOP-k and DGC (the other engine-backed selectors).
#[test]
fn topk_and_dgc_trajectories_bit_identical_across_shard_counts() {
    for kind in [
        SparsifierKind::TopK { k: 7 },
        SparsifierKind::Dgc { k: 7, momentum: 0.9, clip: 0.0 },
    ] {
        let dim = 400;
        let mut serial = build(&kind, dim, 0);
        let mut sharded = build(&kind, dim, 0);
        sharded.set_shards(5);
        let mut rng = Rng::seed_from(77);
        let gagg = vec![0.0f32; dim];
        let mut out = SparseVec::zeros(dim);
        for t in 0..8 {
            let g = rng.gaussian_vec(dim, 1.0);
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.25, genie_acc: None };
            let want = serial.step(&g, &ctx);
            sharded.step_into(&g, &ctx, &mut out);
            assert_eq!(want, out, "{kind:?} t={t}");
        }
    }
}

/// End-to-end: the fig2 trainer with the engine fully on (shards=8,
/// forced through the config) matches the seed serial trainer bitwise
/// over a full training run — model, losses, and upload accounting.
#[test]
fn trainer_bit_identical_with_sharding_enabled() {
    let params = LinearParams { workers: 4, rows_per_worker: 80, dim: 24, ..LinearParams::fig2() };
    let problem = generate(params, 11);
    for kind in [
        SparsifierKind::TopK { k: 8 },
        SparsifierKind::RegTopK { k: 8, mu: 0.5, q: 1.0 },
    ] {
        let mut serial = fig2::trainer_for(&problem, kind.clone(), 0.02);
        // dim 24 is below the trainer's auto threshold, so force the
        // engine directly onto the workers to exercise the full path
        let mut sharded = fig2::trainer_for(&problem, kind.clone(), 0.02);
        for w in &mut sharded.workers {
            w.set_shards(8);
        }
        for _ in 0..40 {
            serial.round();
            sharded.round();
        }
        assert_eq!(serial.server.w, sharded.server.w, "{kind:?}");
        assert_eq!(
            serial.ledger.total_upload_bytes(),
            sharded.ledger.total_upload_bytes(),
            "{kind:?}"
        );
    }
}
