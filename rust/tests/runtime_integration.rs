//! Integration: load real AOT artifacts through PJRT and validate the
//! numerics against rust-native reference computations.
//!
//! Requires `make artifacts` (skipped gracefully when absent so plain
//! `cargo test` works pre-build; CI/`make test` always builds first).

use regtopk::runtime::{Runtime, Tensor};
use regtopk::sparsify::RegTopK;
use regtopk::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping (artifacts not built): {e}");
            None
        }
    }
}

#[test]
fn linreg_grad_matches_rust_native() {
    let Some(mut rt) = runtime() else { return };
    let (j, d) = (100usize, 500usize);
    let mut rng = Rng::seed_from(11);
    let w = rng.gaussian_vec(j, 1.0);
    let x = rng.gaussian_vec(d * j, 1.0);
    let y = rng.gaussian_vec(d, 1.0);

    let exe = rt.load("linreg_grad").unwrap();
    let out = exe
        .call(&[
            Tensor::f32(w.clone(), &[j]),
            Tensor::f32(x.clone(), &[d, j]),
            Tensor::f32(y.clone(), &[d]),
        ])
        .unwrap();
    assert_eq!(out.len(), 2);
    let (hlo_loss, hlo_grad) = (&out[0], &out[1]);
    assert_eq!(hlo_loss.len(), 1);
    assert_eq!(hlo_grad.len(), j);

    // rust-native LS gradient on the same data
    let shard = regtopk::data::Shard { x, y, rows: d, dim: j };
    let mut g = vec![0.0f32; j];
    let loss = regtopk::data::linear::ls_gradient(&shard, &w, &mut g);
    assert!(
        (hlo_loss[0] - loss).abs() <= 1e-4 * loss.abs().max(1.0),
        "loss {} vs {}",
        hlo_loss[0],
        loss
    );
    for i in 0..j {
        assert!(
            (hlo_grad[i] - g[i]).abs() <= 2e-3 * g[i].abs().max(1.0),
            "grad[{i}] {} vs {}",
            hlo_grad[i],
            g[i]
        );
    }
}

#[test]
fn regtopk_score_artifact_matches_rust_native() {
    let Some(mut rt) = runtime() else { return };
    let spec = rt.manifest.artifacts["regtopk_score"].clone();
    let j = spec.inputs[0].shape[0];
    let mut rng = Rng::seed_from(22);
    let eps = rng.gaussian_vec(j, 1.0);
    let g = rng.gaussian_vec(j, 1.0);
    let acc_prev = rng.gaussian_vec(j, 1.0);
    let gagg_prev = rng.gaussian_vec(j, 1.0);
    let mask_prev: Vec<f32> = (0..j).map(|_| (rng.below(2)) as f32).collect();
    let (omega, mu, q) = (0.125f32, 0.5f32, 1.0f32);

    let exe = rt.load("regtopk_score").unwrap();
    let out = exe
        .call(&[
            Tensor::f32(eps.clone(), &[j]),
            Tensor::f32(g.clone(), &[j]),
            Tensor::f32(acc_prev.clone(), &[j]),
            Tensor::f32(gagg_prev.clone(), &[j]),
            Tensor::f32(mask_prev.clone(), &[j]),
            Tensor::f32(vec![omega, mu, q], &[3]),
        ])
        .unwrap();
    let (hlo_acc, hlo_score) = (&out[0], &out[1]);

    // rust-native: acc + score
    let acc: Vec<f32> = eps.iter().zip(&g).map(|(a, b)| a + b).collect();
    let mut score = vec![0.0f32; j];
    RegTopK::compute_score(&acc, &acc_prev, &gagg_prev, &mask_prev, omega, mu, q, &mut score);

    for i in 0..j {
        assert_eq!(hlo_acc[i], acc[i], "acc[{i}]");
        assert!(
            (hlo_score[i] - score[i]).abs() <= 1e-5 * score[i].abs().max(1e-3),
            "score[{i}] {} vs {}",
            hlo_score[i],
            score[i]
        );
    }

    // selection agreement: same top-k set under both scores
    let k = 1000;
    let a = regtopk::sparse::select_topk(hlo_score, k);
    let b = regtopk::sparse::select_topk(&score, k);
    let same = a.iter().zip(&b).filter(|(x, y)| x == y).count();
    assert!(same as f64 > 0.999 * k as f64, "selection overlap {same}/{k}");
}

#[test]
fn error_feedback_artifact_conserves() {
    let Some(mut rt) = runtime() else { return };
    let spec = rt.manifest.artifacts["error_feedback"].clone();
    let j = spec.inputs[0].shape[0];
    let mut rng = Rng::seed_from(33);
    let acc = rng.gaussian_vec(j, 10.0);
    let mask: Vec<f32> = (0..j).map(|_| (rng.below(2)) as f32).collect();
    let exe = rt.load("error_feedback").unwrap();
    let out = exe
        .call(&[Tensor::f32(acc.clone(), &[j]), Tensor::f32(mask.clone(), &[j])])
        .unwrap();
    let (ghat, eps) = (&out[0], &out[1]);
    for i in 0..j {
        assert_eq!(ghat[i] + eps[i], acc[i], "conservation at {i}");
        assert!(ghat[i] == 0.0 || eps[i] == 0.0, "support overlap at {i}");
    }
}

#[test]
fn sgd_apply_artifact_matches_native() {
    let Some(mut rt) = runtime() else { return };
    let spec = rt.manifest.artifacts["sgd_apply"].clone();
    let j = spec.inputs[0].shape[0];
    let mut rng = Rng::seed_from(44);
    let w = rng.gaussian_vec(j, 1.0);
    let g = rng.gaussian_vec(j, 1.0);
    let eta = 0.01f32;
    let exe = rt.load("sgd_apply").unwrap();
    let out = exe
        .call(&[
            Tensor::f32(w.clone(), &[j]),
            Tensor::f32(g.clone(), &[j]),
            Tensor::f32(vec![eta], &[1]),
        ])
        .unwrap();
    for i in 0..j {
        let want = w[i] - eta * g[i];
        assert!((out[0][i] - want).abs() <= 1e-6 * want.abs().max(1e-3), "{i}");
    }
}

#[test]
fn mlp_grad_descends_on_its_init() {
    let Some(mut rt) = runtime() else { return };
    let w = rt.load_init("mlp").unwrap();
    let spec = rt.manifest.artifacts["mlp_grad"].clone();
    let b = spec.inputs[1].shape[0];
    let mut rng = Rng::seed_from(55);
    let x = rng.gaussian_vec(b * 3072, 0.5);
    let y: Vec<i32> = (0..b).map(|_| rng.below(10) as i32).collect();
    let exe = rt.load("mlp_grad").unwrap();
    let call = |w: &[f32]| {
        exe.call(&[
            Tensor::f32(w.to_vec(), &[w.len()]),
            Tensor::f32(x.clone(), &[b, 3072]),
            Tensor::i32(y.clone(), &[b]),
        ])
        .unwrap()
    };
    let out = call(&w);
    let (loss0, grad) = (out[0][0], &out[1]);
    assert!(loss0.is_finite() && loss0 > 0.0);
    assert_eq!(grad.len(), w.len());
    let w2: Vec<f32> = w.iter().zip(grad).map(|(wi, gi)| wi - 0.05 * gi).collect();
    let loss1 = call(&w2)[0][0];
    assert!(loss1 < loss0, "descent: {loss1} !< {loss0}");
}
