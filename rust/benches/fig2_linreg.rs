//! Fig. 2 regeneration bench: per-round latency of the full
//! coordinator at the paper's geometry (N=20, D=500, J=100), per
//! algorithm, plus a complete figure regeneration timing.
//!
//!     cargo bench --bench fig2_linreg

use regtopk::data::linear::{generate, LinearParams};
use regtopk::experiments::fig2;
use regtopk::sparsify::SparsifierKind;
use regtopk::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    println!("# Fig.2 per-round coordinator latency (paper geometry)");
    let problem = generate(LinearParams::fig2(), 42);
    for (name, kind) in [
        ("dense", SparsifierKind::Dense),
        ("topk", SparsifierKind::TopK { k: 60 }),
        ("regtopk", SparsifierKind::RegTopK { k: 60, mu: 0.5, q: 1.0 }),
        ("gtopk", SparsifierKind::GlobalTopK { k: 60 }),
    ] {
        let mut tr = fig2::trainer_for(&problem, kind, 0.01);
        b.run(&format!("fig2/round/{name}"), || {
            black_box(tr.round());
        });
    }
    println!("\n# full-figure regeneration (3 sparsities x 2 algos + dense, 300 iters)");
    b.run("fig2/figure/300it", || {
        black_box(fig2::run(
            LinearParams::fig2(),
            42,
            300,
            &[0.4, 0.5, 0.6],
            0.5,
            1.0,
            0.01,
        ));
    });
}
