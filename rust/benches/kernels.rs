//! Kernel-layer benchmarks (ISSUE 10): chunked vs scalar-referee
//! throughput for each hot-path primitive, at J = 2^16 and 2^20.
//!
//!     cargo bench --bench kernels
//!
//! Results land in BENCH_PR10.json (override with $BENCH_JSON):
//! `kernels/<name>/{chunked,scalar}/J=<J>` entries with
//! median_s/melem_per_s.  Every timed point re-asserts the layer's
//! contract inline — the chunked output is BIT-identical to the
//! referee's — so a run that reports a speedup on divergent results
//! is impossible.

use std::path::Path;

use regtopk::util::bench::{black_box, Bench};
use regtopk::util::kernels::{
    abs_hist, abs_hist_ref, bf16_to_f32_slice, bf16_to_f32_slice_ref, f32_to_bf16_codes,
    f32_to_bf16_codes_ref, fill_abs_hist, fill_abs_hist_ref, pack_fixed, pack_fixed_ref,
    scatter_add, scatter_add_ref, unpack_fixed, unpack_fixed_ref,
};
use regtopk::util::rng::Rng;

fn bench_json_path() -> String {
    std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_PR10.json".to_string())
}

fn bits_of(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

fn main() {
    let mut b = Bench::new();
    for j in [1usize << 16, 1 << 20] {
        let mut rng = Rng::seed_from(10);
        let x: Vec<f32> = (0..j).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        // ---- fused fill + magnitude histogram ----------------------
        let fill = |lo: usize, block: &mut [f32]| {
            for (i, slot) in block.iter_mut().enumerate() {
                *slot = ((lo + i) as f32 - 7.0) * 0.03125;
            }
        };
        let (mut buf, mut h) = (vec![0.0f32; j], [0u32; 256]);
        b.run_throughput(&format!("kernels/fill_hist/chunked/J={j}"), j, || {
            fill_abs_hist(0, &mut buf, &mut h, fill);
            black_box(h[0]);
        });
        let (mut rbuf, mut rh) = (vec![0.0f32; j], [0u32; 256]);
        b.run_throughput(&format!("kernels/fill_hist/scalar/J={j}"), j, || {
            fill_abs_hist_ref(0, &mut rbuf, &mut rh, fill);
            black_box(rh[0]);
        });
        assert_eq!(bits_of(&buf), bits_of(&rbuf), "fill_hist buffer diverged at J={j}");
        assert_eq!(h, rh, "fill_hist histogram diverged at J={j}");

        let mut h2 = [0u32; 256];
        b.run_throughput(&format!("kernels/abs_hist/chunked/J={j}"), j, || {
            h2.fill(0);
            abs_hist(&x, &mut h2);
            black_box(h2[128]);
        });
        let mut rh2 = [0u32; 256];
        b.run_throughput(&format!("kernels/abs_hist/scalar/J={j}"), j, || {
            rh2.fill(0);
            abs_hist_ref(&x, &mut rh2);
            black_box(rh2[128]);
        });
        assert_eq!(h2, rh2, "abs_hist diverged at J={j}");

        // ---- merge scatter-add (k = J/64 entries, duplicates) ------
        let k = j / 64;
        let idx: Vec<u32> = (0..k).map(|_| rng.below(j) as u32).collect();
        let val: Vec<f32> = (0..k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut acc = vec![0.0f32; j];
        b.run_throughput(&format!("kernels/scatter_add/chunked/k={k}"), k, || {
            acc.fill(0.0);
            scatter_add(&mut acc, &idx, &val, 0.25);
            black_box(acc[idx[0] as usize]);
        });
        let mut racc = vec![0.0f32; j];
        b.run_throughput(&format!("kernels/scatter_add/scalar/k={k}"), k, || {
            racc.fill(0.0);
            scatter_add_ref(&mut racc, &idx, &val, 0.25);
            black_box(racc[idx[0] as usize]);
        });
        assert_eq!(bits_of(&acc), bits_of(&racc), "scatter_add diverged at k={k}");

        // ---- fixed-width bit pack / unpack at the codec's 5 bits ---
        let bits = 5usize;
        let codes: Vec<u32> = (0..j).map(|_| (rng.next_u64() & 0x1F) as u32).collect();
        let (mut w, mut rw) = (Vec::new(), Vec::new());
        b.run_throughput(&format!("kernels/pack_fixed/chunked/J={j}"), j, || {
            pack_fixed(&codes, bits, &mut w);
            black_box(w.len());
        });
        b.run_throughput(&format!("kernels/pack_fixed/scalar/J={j}"), j, || {
            pack_fixed_ref(&codes, bits, &mut rw);
            black_box(rw.len());
        });
        assert_eq!(w, rw, "pack_fixed diverged at J={j}");
        let (mut u, mut ru) = (Vec::new(), Vec::new());
        b.run_throughput(&format!("kernels/unpack_fixed/chunked/J={j}"), j, || {
            unpack_fixed(&w, bits, j, &mut u);
            black_box(u.len());
        });
        b.run_throughput(&format!("kernels/unpack_fixed/scalar/J={j}"), j, || {
            unpack_fixed_ref(&w, bits, j, &mut ru);
            black_box(ru.len());
        });
        assert_eq!(u, codes, "unpack_fixed is not the pack inverse at J={j}");
        assert_eq!(ru, codes, "referee unpack diverged at J={j}");

        // ---- half-width wire converts (bf16 axis) ------------------
        let (mut c, mut rc) = (Vec::new(), Vec::new());
        b.run_throughput(&format!("kernels/bf16_encode/chunked/J={j}"), j, || {
            f32_to_bf16_codes(&x, &mut c);
            black_box(c.len());
        });
        b.run_throughput(&format!("kernels/bf16_encode/scalar/J={j}"), j, || {
            f32_to_bf16_codes_ref(&x, &mut rc);
            black_box(rc.len());
        });
        assert_eq!(c, rc, "bf16 encode diverged at J={j}");
        let (mut d, mut rd) = (Vec::new(), Vec::new());
        b.run_throughput(&format!("kernels/bf16_decode/chunked/J={j}"), j, || {
            bf16_to_f32_slice(&c, &mut d);
            black_box(d.len());
        });
        b.run_throughput(&format!("kernels/bf16_decode/scalar/J={j}"), j, || {
            bf16_to_f32_slice_ref(&c, &mut rd);
            black_box(rd.len());
        });
        assert_eq!(bits_of(&d), bits_of(&rd), "bf16 decode diverged at J={j}");
    }

    let path = bench_json_path();
    b.write_json(Path::new(&path)).unwrap_or_else(|e| eprintln!("# could not write {path}: {e}"));
    println!("# kernel points are chunked/scalar pairs; bit-identity asserted inline");
}
