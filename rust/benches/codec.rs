//! Wire-codec benchmarks (ISSUE 5): encode/decode throughput per
//! codec pair (Golomb–Rice index coding on clustered vs uniform index
//! sets; uniform vs NUQ value packing) and the bound-vs-code byte
//! points — measured Rice index bytes against the paper's bit-packed
//! `log J` accounting.
//!
//!     cargo bench --bench codec
//!
//! Results merge into BENCH_PR5.json (override with $BENCH_JSON):
//! `codec/*` entries carry median_s/melem_per_s; the `codec_bytes/*`
//! entries carry `rice_bytes` vs `packed_bytes` for one bucket's index
//! stream.  The clustered point is the acceptance gate: the entropy
//! code must decode losslessly AND beat the packed bound there.

use std::collections::BTreeMap;
use std::path::Path;

use regtopk::comm::codec::{index_bits, LevelKind, QuantPayload, RicePayload, ValueCodec};
use regtopk::sparse::SparseVec;
use regtopk::util::bench::{black_box, Bench};
use regtopk::util::json::Json;
use regtopk::util::rng::Rng;

fn bench_json_path() -> String {
    std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_PR5.json".to_string())
}

/// Merge `(key, rice_bytes, packed_bytes)` points into the bench JSON
/// (preserving the timing entries written by `Bench::write_json`).
fn merge_byte_points(path: &str, points: &[(String, usize, usize)]) {
    let mut map: BTreeMap<String, Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    for (key, rice, packed) in points {
        let mut entry = BTreeMap::new();
        entry.insert("rice_bytes".to_string(), Json::from(*rice));
        entry.insert("packed_bytes".to_string(), Json::from(*packed));
        map.insert(format!("codec_bytes/{key}"), Json::Obj(entry));
    }
    match std::fs::write(Path::new(path), Json::Obj(map).dump()) {
        Ok(()) => println!("# wrote {} byte points to {path}", points.len()),
        Err(e) => eprintln!("# could not write {path}: {e}"),
    }
}

/// k sorted indices sampled uniformly from a `window`-wide span of a
/// dim-`dim` group (window == dim: the uniform worst case; window <<
/// dim: the clustered regime error feedback produces in practice).
fn indices(dim: usize, window: usize, k: usize, rng: &mut Rng) -> Vec<u32> {
    let mut idx: Vec<u32> =
        rng.sample_indices(window.min(dim), k).into_iter().map(|i| i as u32).collect();
    idx.sort_unstable();
    idx
}

fn main() {
    let mut b = Bench::new();
    let dim = 1 << 20;
    let k = 1024usize;
    println!(
        "# wire codecs: k={k} entries of a J={dim} group ({} packed index bits)",
        index_bits(dim)
    );

    let mut byte_points: Vec<(String, usize, usize)> = Vec::new();

    // ---- index axis: Golomb-Rice on clustered vs uniform sets ------
    for (name, window) in [("clustered", 8 * k), ("uniform", dim)] {
        let mut rng = Rng::seed_from(1);
        let idx = indices(dim, window, k, &mut rng);
        let mut p = RicePayload::default();
        b.run_throughput(&format!("codec/rice_encode/{name}/k={k}"), k, || {
            p.encode_into(&idx);
            black_box(p.param());
        });
        let mut out = Vec::with_capacity(k);
        b.run_throughput(&format!("codec/rice_decode/{name}/k={k}"), k, || {
            p.decode_into(&mut out);
            black_box(out.len());
        });
        assert_eq!(out, idx, "rice decode must be lossless ({name})");
        let packed = (k * index_bits(dim)).div_ceil(8);
        byte_points.push((format!("{name}/k={k}/J={dim}"), p.wire_bytes(), packed));
    }
    // the acceptance gate: entropy-coded indices beat the bit-packed
    // log J bound on the clustered bucket
    let (rice_c, packed_c) = (byte_points[0].1, byte_points[0].2);
    assert!(
        rice_c < packed_c,
        "clustered rice {rice_c} B must beat packed {packed_c} B"
    );

    // ---- value axis: uniform vs NUQ packing at 4 bits --------------
    for (name, levels) in [("uniform", LevelKind::Uniform), ("nuq", LevelKind::Nuq)] {
        let vc = ValueCodec { bits: 4, levels };
        let mut rng = Rng::seed_from(2);
        let idx = indices(dim, dim, k, &mut rng);
        let vals: Vec<f32> = (0..k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let proto = SparseVec::new(dim, idx, vals);
        let mut payload = QuantPayload::default();
        let (mut residual, mut codes) = (Vec::new(), Vec::new());
        let mut work = proto.clone();
        b.run_throughput(&format!("codec/value_encode/{name}/bits=4/k={k}"), k, || {
            work = proto.clone();
            vc.encode_bucket(&mut work, &mut rng, &mut payload, &mut residual, &mut codes);
            black_box(payload.scale());
        });
        let mut out = vec![0.0f32; k];
        b.run_throughput(&format!("codec/value_decode/{name}/bits=4/k={k}"), k, || {
            for (i, o) in out.iter_mut().enumerate() {
                *o = payload.decode_value(i);
            }
            black_box(out[k - 1]);
        });
        assert_eq!(out, work.values(), "decode must reproduce the bucket ({name})");
    }

    let path = bench_json_path();
    b.write_json(Path::new(&path)).unwrap_or_else(|e| eprintln!("# could not write {path}: {e}"));
    merge_byte_points(&path, &byte_points);
    println!("\n# per-bucket index bytes (k={k}): measured rice vs the packed log J bound");
    for (key, rice, packed) in &byte_points {
        println!(
            "  {key:<28} rice {rice:>7} B   packed {packed:>7} B   saving {:.2}%",
            100.0 * (1.0 - *rice as f64 / (*packed).max(1) as f64)
        );
    }
}
