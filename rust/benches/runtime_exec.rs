//! PJRT runtime round-trip latency: the L3 <-> artifact boundary.
//! Measures compile-once/execute-many for the gradient executables and
//! the standalone kernels (this is the per-round per-worker cost of
//! the artifact-backed path in Fig. 3).
//!
//!     cargo bench --bench runtime_exec   (requires `make artifacts`)

use regtopk::runtime::{Runtime, Tensor};
use regtopk::util::bench::{black_box, Bench};
use regtopk::util::rng::Rng;

fn main() {
    let mut rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping runtime benches (no artifacts): {e}");
            return;
        }
    };
    let mut b = Bench::new();
    let mut rng = Rng::seed_from(1);

    // linreg grad: J=100, D=500
    let exe = rt.load("linreg_grad").unwrap();
    let w = rng.gaussian_vec(100, 1.0);
    let x = rng.gaussian_vec(500 * 100, 1.0);
    let y = rng.gaussian_vec(500, 1.0);
    b.run("runtime/linreg_grad(J=100,D=500)", || {
        black_box(
            exe.call(&[
                Tensor::f32(w.clone(), &[100]),
                Tensor::f32(x.clone(), &[500, 100]),
                Tensor::f32(y.clone(), &[500]),
            ])
            .unwrap(),
        );
    });

    // regtopk score kernel at J=2^17
    let exe = rt.load("regtopk_score").unwrap();
    let j = exe.spec.inputs[0].shape[0];
    let vecs: Vec<Vec<f32>> = (0..5).map(|_| rng.gaussian_vec(j, 1.0)).collect();
    b.run_throughput(&format!("runtime/regtopk_score(J={j})"), j, || {
        black_box(
            exe.call(&[
                Tensor::f32(vecs[0].clone(), &[j]),
                Tensor::f32(vecs[1].clone(), &[j]),
                Tensor::f32(vecs[2].clone(), &[j]),
                Tensor::f32(vecs[3].clone(), &[j]),
                Tensor::f32(vecs[4].clone(), &[j]),
                Tensor::f32(vec![0.125, 0.5, 1.0], &[3]),
            ])
            .unwrap(),
        );
    });

    // resnet8 grad step (the Fig.3 per-worker cost)
    let exe = rt.load("cnn_grad_resnet8").unwrap();
    let jw = exe.spec.inputs[0].shape[0];
    let wv = rt.load_init("resnet8").unwrap();
    let xb = rng.gaussian_vec(20 * 32 * 32 * 3, 0.5);
    let yb: Vec<i32> = (0..20).map(|i| (i % 10) as i32).collect();
    b.run(&format!("runtime/cnn_grad_resnet8(J={jw},B=20)"), || {
        black_box(
            exe.call(&[
                Tensor::f32(wv.clone(), &[jw]),
                Tensor::f32(xb.clone(), &[20, 32, 32, 3]),
                Tensor::i32(yb.clone(), &[20]),
            ])
            .unwrap(),
        );
    });
}
