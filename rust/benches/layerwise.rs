//! Layer-wise API benchmarks: flat (single-group) vs grouped select
//! throughput for one RegTop-k worker step, plus the wire-cost points
//! of the bucketed update format (per-group index bits vs flat
//! `log2 J` bits).
//!
//!     cargo bench --bench layerwise
//!
//! Results merge into BENCH_PR2.json (override with $BENCH_JSON):
//! `layerwise/*` entries carry median_s/melem_per_s; the
//! `layerwise_bytes/*` entries carry `grouped_bytes` vs `flat_bytes`
//! for one sparsified update (the per-group upload saving the ledger
//! reports per round).

use std::collections::BTreeMap;
use std::path::Path;

use regtopk::grad::{GradLayout, GradView};
use regtopk::comm::SparseUpdate;
use regtopk::sparsify::{
    build, BudgetPolicy, LayerwiseSparsifier, RoundCtx, Sparsifier, SparsifierKind,
};
use regtopk::util::bench::{black_box, Bench};
use regtopk::util::json::Json;
use regtopk::util::rng::Rng;

fn bench_json_path() -> String {
    std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_PR2.json".to_string())
}

/// Merge `(key, grouped_bytes, flat_bytes)` points into the bench JSON
/// (preserving the timing entries written by `Bench::write_json`).
fn merge_byte_points(path: &str, points: &[(String, usize, usize)]) {
    let mut map: BTreeMap<String, Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    for (key, grouped, flat) in points {
        let mut entry = BTreeMap::new();
        entry.insert("grouped_bytes".to_string(), Json::from(*grouped));
        entry.insert("flat_bytes".to_string(), Json::from(*flat));
        map.insert(format!("layerwise_bytes/{key}"), Json::Obj(entry));
    }
    match std::fs::write(Path::new(path), Json::Obj(map).dump()) {
        Ok(()) => println!("# wrote {} byte points to {path}", points.len()),
        Err(e) => eprintln!("# could not write {path}: {e}"),
    }
}

fn main() {
    let mut b = Bench::new();
    let j = 1_000_000usize;
    let s = 0.001f64;
    let k = (j as f64 * s) as usize;
    let mut rng = Rng::seed_from(1);
    let grad = rng.gaussian_vec(j, 1.0);
    let gagg = rng.gaussian_vec(j, 0.2);
    let kind = SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 };
    println!("# layer-wise select: flat single group vs G equal groups (J={j}, S={s})");

    // flat reference: the degenerate single-group layout
    {
        let layout = GradLayout::single(j);
        let mut sp = build(&kind, j, 0);
        let mut out = SparseUpdate::empty();
        let mut t = 0usize;
        b.run_throughput(&format!("layerwise/flat/J={j}/S={s}"), j, || {
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.125, genie_acc: None };
            let view = GradView::new(&layout, &grad);
            sp.step_group_into(&view, &ctx, &mut out);
            black_box(out.nnz());
            t += 1;
        });
    }

    // grouped: G equal groups, proportional budget (same total k)
    let mut byte_points: Vec<(String, usize, usize)> = Vec::new();
    for &groups in &[8usize, 64] {
        let layout =
            GradLayout::from_sizes((0..groups).map(|g| (format!("g{g}"), j / groups)));
        assert_eq!(layout.total(), j, "J must divide evenly into {groups} groups");
        let mut lw =
            LayerwiseSparsifier::new(&kind, layout.clone(), &BudgetPolicy::Proportional { frac: s }, 0);
        let mut out = SparseUpdate::empty();
        let mut t = 0usize;
        b.run_throughput(&format!("layerwise/G={groups}/J={j}/S={s}"), j, || {
            let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.125, genie_acc: None };
            let view = GradView::new(&layout, &grad);
            lw.step_group_into(&view, &ctx, &mut out);
            black_box(out.nnz());
            t += 1;
        });
        // wire-cost point: the same update bucketed vs flattened
        let wc = regtopk::comm::codec::WireCost::paper();
        byte_points.push((
            format!("G={groups}/J={j}/S={s}"),
            wc.update(&out),
            wc.flat(&out.flatten()),
        ));
    }

    let path = bench_json_path();
    b.write_json(Path::new(&path)).unwrap_or_else(|e| eprintln!("# could not write {path}: {e}"));
    merge_byte_points(&path, &byte_points);
    println!("\n# per-update upload bytes (one worker, k = {k} entries total)");
    for (key, grouped, flat) in &byte_points {
        println!(
            "  {key:<24} grouped {grouped:>8} B   flat {flat:>8} B   saving {:.2}%",
            100.0 * (1.0 - *grouped as f64 / (*flat).max(1) as f64)
        );
    }
}
