//! Heterogeneous layer-wise sparsification benchmarks (ISSUE 3):
//! one RegTop-k worker step over a CNN-shaped multi-group layout —
//! homogeneous vs heterogeneous (dense biases + Top-k tail) — plus the
//! per-group shard-clamp observability and the bucketed wire-cost
//! points of each variant.
//!
//!     cargo bench --bench heterogeneous
//!
//! Results merge into BENCH_PR3.json (override with $BENCH_JSON):
//! `hetero/*` entries carry median_s/melem_per_s; `hetero_bytes/*`
//! entries carry grouped vs flat wire bytes for one sparsified update.

use std::collections::BTreeMap;
use std::path::Path;

use regtopk::grad::{GradLayout, GradView};
use regtopk::sparsify::{
    BudgetPolicy, LayerwiseSparsifier, PolicyTable, RoundCtx, Sparsifier, SparsifierKind,
};
use regtopk::util::bench::{black_box, Bench};
use regtopk::util::json::Json;
use regtopk::util::rng::Rng;

fn bench_json_path() -> String {
    std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_PR3.json".to_string())
}

/// A ResNet-ish layout: big kernel blocks interleaved with tiny bias
/// vectors (the shape that exercises the per-group shard clamp).
fn cnn_layout(j: usize) -> GradLayout {
    let blocks = 8usize;
    let bias = 64usize;
    let kernel = (j - blocks * bias) / blocks;
    let mut sizes = Vec::new();
    let mut used = 0usize;
    for b in 0..blocks {
        let k = if b + 1 == blocks { j - used - bias } else { kernel };
        sizes.push((format!("block{b}.w"), k));
        sizes.push((format!("block{b}.b"), bias));
        used += k + bias;
    }
    let layout = GradLayout::from_sizes(sizes);
    assert_eq!(layout.total(), j);
    layout
}

fn merge_byte_points(path: &str, points: &[(String, usize, usize)]) {
    let mut map: BTreeMap<String, Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    for (key, grouped, flat) in points {
        let mut entry = BTreeMap::new();
        entry.insert("grouped_bytes".to_string(), Json::from(*grouped));
        entry.insert("flat_bytes".to_string(), Json::from(*flat));
        map.insert(format!("hetero_bytes/{key}"), Json::Obj(entry));
    }
    match std::fs::write(Path::new(path), Json::Obj(map).dump()) {
        Ok(()) => println!("# wrote {} byte points to {path}", points.len()),
        Err(e) => eprintln!("# could not write {path}: {e}"),
    }
}

fn main() {
    let mut b = Bench::new();
    let j = 1 << 20;
    let s = 0.001f64;
    let k = (j as f64 * s) as usize;
    let mut rng = Rng::seed_from(3);
    let grad = rng.gaussian_vec(j, 1.0);
    let gagg = rng.gaussian_vec(j, 0.2);
    let layout = cnn_layout(j);
    let kind = SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 };
    let budget = BudgetPolicy::Global { k };
    println!(
        "# heterogeneous layer-wise step (J={j}, {} groups, k={k})",
        layout.num_groups()
    );

    let variants: Vec<(&str, PolicyTable)> = vec![
        ("homogeneous", PolicyTable::default()),
        (
            "hetero",
            PolicyTable::parse("*.b=dense;block0*=regtopk:mu=0.3;*=topk").unwrap(),
        ),
    ];
    let mut byte_points = Vec::new();
    for (name, table) in &variants {
        for &shards in &[1usize, 8] {
            let mut lw =
                LayerwiseSparsifier::with_policies(&kind, layout.clone(), &budget, table, 0);
            lw.set_shards(shards);
            if shards > 1 {
                // the over-sharding fix: tiny bias groups stay serial
                use regtopk::sparse::engine::MIN_SHARDED_DIM;
                let cs = lw.child_shards();
                assert!(cs.iter().zip(layout.groups()).all(|(&c, g)| {
                    if g.len < MIN_SHARDED_DIM { c == 1 } else { c == shards }
                }));
            }
            let mut out = regtopk::comm::SparseUpdate::empty();
            let mut t = 0usize;
            b.run_throughput(&format!("hetero/{name}/shards={shards}/J={j}"), j, || {
                let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.125, genie_acc: None };
                let view = GradView::new(&layout, &grad);
                lw.step_group_into(&view, &ctx, &mut out);
                black_box(out.nnz());
                t += 1;
            });
            if shards == 1 {
                let wc = regtopk::comm::codec::WireCost::paper();
                byte_points.push((
                    format!("{name}/J={j}"),
                    wc.update(&out),
                    wc.flat(&out.flatten()),
                ));
            }
        }
    }

    let path = bench_json_path();
    b.write_json(Path::new(&path))
        .unwrap_or_else(|e| eprintln!("# could not write {path}: {e}"));
    merge_byte_points(&path, &byte_points);
    println!("\n# per-update upload bytes (one worker)");
    for (key, grouped, flat) in &byte_points {
        println!(
            "  {key:<28} grouped {grouped:>9} B   flat {flat:>9} B   saving {:.2}%",
            100.0 * (1.0 - *grouped as f64 / (*flat).max(1) as f64)
        );
    }
}
