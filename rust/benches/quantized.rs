//! Quantized-transmission benchmarks (ISSUE 4): packed-payload
//! encode/decode throughput across bit widths, the full worker-side
//! quantize-bucket pass (stochastic rounding + residual + packing),
//! and the wire-byte points of quantized vs raw buckets.
//!
//!     cargo bench --bench quantized
//!
//! Results merge into BENCH_PR4.json (override with $BENCH_JSON):
//! `quantized/*` entries carry median_s/melem_per_s; the
//! `quantized_bytes/*` entries carry `packed_bytes` vs `raw_bytes`
//! for one sparsified update (the upload saving the ledger reports
//! per round under a `bits` policy).

use std::collections::BTreeMap;
use std::path::Path;

use regtopk::comm::codec::{LevelKind, QuantPayload, ValueCodec, WireCost};
use regtopk::sparse::SparseVec;
use regtopk::util::bench::{black_box, Bench};
use regtopk::util::json::Json;
use regtopk::util::rng::Rng;

fn bench_json_path() -> String {
    std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_PR4.json".to_string())
}

/// Merge `(key, packed_bytes, raw_bytes)` points into the bench JSON
/// (preserving the timing entries written by `Bench::write_json`).
fn merge_byte_points(path: &str, points: &[(String, usize, usize)]) {
    let mut map: BTreeMap<String, Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    for (key, packed, raw) in points {
        let mut entry = BTreeMap::new();
        entry.insert("packed_bytes".to_string(), Json::from(*packed));
        entry.insert("raw_bytes".to_string(), Json::from(*raw));
        map.insert(format!("quantized_bytes/{key}"), Json::Obj(entry));
    }
    match std::fs::write(Path::new(path), Json::Obj(map).dump()) {
        Ok(()) => println!("# wrote {} byte points to {path}", points.len()),
        Err(e) => eprintln!("# could not write {path}: {e}"),
    }
}

/// A k-entry bucket of a dim-`dim` group with Gaussian values.
fn bucket(dim: usize, k: usize, rng: &mut Rng) -> SparseVec {
    let mut idx: Vec<u32> = rng.sample_indices(dim, k).into_iter().map(|i| i as u32).collect();
    idx.sort_unstable();
    let vals: Vec<f32> = (0..k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    SparseVec::new(dim, idx, vals)
}

fn main() {
    let mut b = Bench::new();
    let dim = 1 << 20;
    let k = 1024usize;
    println!("# quantized transmission: k={k} entries of a J={dim} group");

    let mut byte_points: Vec<(String, usize, usize)> = Vec::new();
    for &bits in &[4usize, 8] {
        let quant = ValueCodec { bits, levels: LevelKind::Uniform };
        // full worker-side pass: stochastic round + residual + pack
        {
            let mut rng = Rng::seed_from(1);
            let proto = bucket(dim, k, &mut rng);
            let mut payload = QuantPayload::default();
            let (mut residual, mut codes) = (Vec::new(), Vec::new());
            let mut work = proto.clone();
            b.run_throughput(&format!("quantized/quantize_bucket/bits={bits}/k={k}"), k, || {
                work = proto.clone();
                quant.encode_bucket(
                    &mut work,
                    &mut rng,
                    &mut payload,
                    &mut residual,
                    &mut codes,
                );
                black_box(payload.scale());
            });
            let raw = WireCost::paper().flat(&proto);
            let index_bits = 20;
            byte_points.push((
                format!("bits={bits}/k={k}/J={dim}"),
                payload.wire_bytes(index_bits),
                raw,
            ));
        }
        // server-side decode alone (the aggregation prerequisite)
        {
            let mut rng = Rng::seed_from(2);
            let mut work = bucket(dim, k, &mut rng);
            let mut payload = QuantPayload::default();
            let (mut residual, mut codes) = (Vec::new(), Vec::new());
            quant.encode_bucket(&mut work, &mut rng, &mut payload, &mut residual, &mut codes);
            let mut out = vec![0.0f32; k];
            b.run_throughput(&format!("quantized/decode/bits={bits}/k={k}"), k, || {
                for (i, o) in out.iter_mut().enumerate() {
                    *o = payload.decode_value(i);
                }
                black_box(out[k - 1]);
            });
            assert_eq!(out, work.values(), "decode must reproduce the bucket");
        }
    }

    let path = bench_json_path();
    b.write_json(Path::new(&path)).unwrap_or_else(|e| eprintln!("# could not write {path}: {e}"));
    merge_byte_points(&path, &byte_points);
    println!("\n# per-update upload bytes (one worker, {k} entries)");
    for (key, packed, raw) in &byte_points {
        println!(
            "  {key:<24} packed {packed:>8} B   raw {raw:>8} B   saving {:.2}%",
            100.0 * (1.0 - *packed as f64 / (*raw).max(1) as f64)
        );
    }
}
