//! Sparsifier throughput: one worker-step per (algorithm, J, S, shards)
//! point.  This is the L3 per-round hot path (error-feedback accumulate
//! + score + select + error update) — the fused sharded engine collapses
//! the three O(J) passes and recycles every buffer (`step_into`).
//!
//!     cargo bench --bench sparsifiers
//!
//! Results are appended to BENCH_PR1.json (override with $BENCH_JSON);
//! EXPERIMENTS.md §Perf records the trajectory.  The acceptance gate of
//! PR 1 compares `*/sh1` (seed-equivalent serial) against `*/shN`.

use regtopk::sparse::SparseVec;
use regtopk::sparsify::{build, RoundCtx, SparsifierKind};
use regtopk::util::bench::{black_box, Bench};
use regtopk::util::pool;
use regtopk::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    let auto = pool::global().parallelism();
    println!("# sparsifier worker-step throughput (elements = J per step; {auto} pool executors)");
    for &j in &[10_000usize, 100_000, 1_000_000] {
        let mut rng = Rng::seed_from(1);
        let grad = rng.gaussian_vec(j, 1.0);
        let gagg = rng.gaussian_vec(j, 0.2);
        for &s in &[0.01f64, 0.001] {
            let k = ((j as f64 * s) as usize).max(1);
            for kind in [
                SparsifierKind::TopK { k },
                SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
                SparsifierKind::Dgc { k, momentum: 0.9, clip: 0.0 },
                SparsifierKind::RandK { k, seed: 3 },
            ] {
                // shards=1: the seed-equivalent serial path; shards=auto:
                // the fused sharded engine on the persistent pool
                for &shards in &[1usize, auto] {
                    if shards > 1 && matches!(kind, SparsifierKind::RandK { .. }) {
                        continue; // randk has no magnitude selection to shard
                    }
                    let mut sp = build(&kind, j, 0);
                    sp.set_shards(shards);
                    let name = format!("{}/J={j}/S={s}/sh{shards}", sp.name());
                    let mut out = SparseVec::zeros(j);
                    // warm the error-feedback state once
                    let ctx = RoundCtx { t: 0, gagg_prev: &gagg, omega: 0.125, genie_acc: None };
                    sp.step_into(&grad, &ctx, &mut out);
                    black_box(out.nnz());
                    let mut t = 1usize;
                    b.run_throughput(&name, j, || {
                        let ctx =
                            RoundCtx { t, gagg_prev: &gagg, omega: 0.125, genie_acc: None };
                        sp.step_into(&grad, &ctx, &mut out);
                        black_box(out.nnz());
                        t += 1;
                    });
                }
            }
        }
    }
    b.write_json_default();
}
