//! Sparsifier throughput: one worker-step per (algorithm, J, S) point.
//! This is the L3 per-round hot path (score + select + error update).
//!
//!     cargo bench --bench sparsifiers

use regtopk::sparsify::{build, RoundCtx, SparsifierKind};
use regtopk::util::bench::{black_box, Bench};
use regtopk::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    println!("# sparsifier worker-step throughput (elements = J per step)");
    for &j in &[10_000usize, 100_000, 1_000_000] {
        let mut rng = Rng::seed_from(1);
        let grad = rng.gaussian_vec(j, 1.0);
        let gagg = rng.gaussian_vec(j, 0.2);
        for &s in &[0.01f64, 0.001] {
            let k = ((j as f64 * s) as usize).max(1);
            for kind in [
                SparsifierKind::TopK { k },
                SparsifierKind::RegTopK { k, mu: 0.5, q: 1.0 },
                SparsifierKind::RandK { k, seed: 3 },
            ] {
                let mut sp = build(&kind, j, 0);
                let name = format!("{}/J={j}/S={s}", sp.name());
                // warm the error-feedback state once
                let ctx = RoundCtx { t: 0, gagg_prev: &gagg, omega: 0.125, genie_acc: None };
                black_box(sp.step(&grad, &ctx));
                let mut t = 1usize;
                b.run_throughput(&name, j, || {
                    let ctx = RoundCtx { t, gagg_prev: &gagg, omega: 0.125, genie_acc: None };
                    black_box(sp.step(&grad, &ctx));
                    t += 1;
                });
            }
        }
    }
}
