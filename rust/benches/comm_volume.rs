//! Tab A regeneration: analytic communication table + measured
//! bytes/round (prints the same rows as `repro comm`).
//!
//!     cargo bench --bench comm_volume

use regtopk::experiments::comm_table;
use regtopk::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    b.run("comm/analytic-table", || {
        black_box(comm_table::analytic(&[0.1, 0.01, 0.001]));
    });
    b.run("comm/measured-10-rounds", || {
        black_box(comm_table::measured(0.01, 10, 42));
    });

    println!("\n# Tab A: analytic symbols/epoch/worker (1000 minibatches)");
    for r in comm_table::analytic(&[0.1, 0.01, 0.001]) {
        println!(
            "  {:<10} J={:<9} S={:<6} symbols/ep {:.3e}  compression {:.5}",
            r.model, r.dim, r.s, r.symbols_per_epoch, r.compression
        );
    }
    println!("\n# measured bytes/round (linreg testbed)");
    for (name, bytes, sim) in comm_table::measured(0.01, 20, 42) {
        println!("  {name:<10} {bytes:>8} B/round  sim {:.3} ms", sim * 1e3);
    }
}
