//! Fig. 1 regeneration bench: full 100-iteration toy runs per
//! algorithm (end-to-end coordinator latency at J=2 scale).
//!
//!     cargo bench --bench fig1_toy

use regtopk::experiments::fig1;
use regtopk::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();
    println!("# Fig.1 toy: 100-iteration end-to-end runs");
    b.run("fig1/all-three-curves/100it", || {
        black_box(fig1::run(100, 0.5, 1.0));
    });
    b.run("fig1/lr-scaling-diagnostic/100it", || {
        black_box(fig1::lr_scaling(100));
    });
    // regenerate the figure data once and print the summary rows
    let logs = fig1::run(100, 0.5, 1.0);
    println!("\n# figure series (final losses)");
    for log in &logs {
        println!("  {:<8} {:.6}", log.name, log.last().unwrap().loss);
    }
}
