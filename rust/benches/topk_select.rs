//! Top-k selection kernels: exact quickselect vs full sort vs sampled
//! threshold (ablation 4) vs the sharded engine.  The selection is the
//! only super-linear step in the sparsifier hot path.
//!
//!     cargo bench --bench topk_select
//!
//! Results are appended to BENCH_PR1.json (override with $BENCH_JSON);
//! EXPERIMENTS.md §Perf records the trajectory.

use regtopk::sparse::engine::SelectEngine;
use regtopk::sparse::topk::{select_topk_quick, select_topk_radix, select_topk_sort};
use regtopk::sparse::select_topk;
use regtopk::sparse::approx;
use regtopk::util::bench::{black_box, Bench};
use regtopk::util::pool;
use regtopk::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    println!(
        "# top-k selection: serial kernels vs sharded engine ({} pool executors)",
        pool::global().parallelism()
    );
    for &j in &[10_000usize, 100_000, 1_000_000] {
        let mut rng = Rng::seed_from(2);
        let x = rng.gaussian_vec(j, 1.0);
        let k = (j / 1000).max(1);
        b.run_throughput(&format!("dispatch/J={j}/k={k}"), j, || {
            black_box(select_topk(&x, k));
        });
        b.run_throughput(&format!("radix/J={j}/k={k}"), j, || {
            black_box(select_topk_radix(&x, k));
        });
        b.run_throughput(&format!("quickselect/J={j}/k={k}"), j, || {
            black_box(select_topk_quick(&x, k));
        });
        if j <= 100_000 {
            b.run_throughput(&format!("fullsort/J={j}/k={k}"), j, || {
                black_box(select_topk_sort(&x, k));
            });
        }
        // the sharded zero-allocation engine at several shard counts
        // (shards=1 exercises the fused structure without the pool)
        let auto = pool::global().parallelism();
        for shards in [1usize, 2, 4, auto] {
            let mut eng = SelectEngine::new(shards);
            let mut out = Vec::new();
            b.run_throughput(&format!("sharded{shards}/J={j}/k={k}"), j, || {
                eng.select_into(&x, k, &mut out);
                black_box(out.len());
            });
        }
        let mut arng = Rng::seed_from(3);
        b.run_throughput(&format!("sampled8/J={j}/k={k}"), j, || {
            black_box(approx::select_topk_sampled(&x, k, 8, &mut arng));
        });
    }
    b.write_json_default();
}
