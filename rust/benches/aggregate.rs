//! Server-side aggregation: dense densify-then-step loop vs the PR 6
//! sparse union merge (`coordinator::merge_updates`).
//!
//!     cargo bench --bench aggregate
//!
//! Grid: k/J in {0.1%, 1%, 10%} x n in {4, 16} workers at J = 2^20.
//! The dense reference pays O(J + n·k) per round (zero-fill plus
//! scatter-adds); the merge pays O(k·n) on the union support.  Results
//! merge into BENCH_PR6.json (override with $BENCH_JSON).
//!
//! Two acceptance gates, checked on every grid point / the paper's
//! regime respectively:
//! - the merged aggregate is bit-identical to the dense reference
//!   (same per-index add order, so not just close — equal),
//! - at 0.1% sparsity (the paper's Fig. 3 regime) the sparse merge
//!   beats the dense loop at both worker counts.

use std::path::Path;

use regtopk::coordinator::merge_updates;
use regtopk::comm::SparseUpdate;
use regtopk::sparse::SparseVec;
use regtopk::util::bench::{black_box, Bench};
use regtopk::util::rng::Rng;

fn bench_json_path() -> String {
    std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_PR6.json".to_string())
}

/// One worker's update: k sorted uniform indices with gaussian values
/// over a flat J-dim layout (uniform supports are the merge's worst
/// case — real top-k unions overlap and shrink the output).
fn worker_update(dim: usize, k: usize, rng: &mut Rng) -> SparseUpdate {
    let mut idx: Vec<u32> =
        rng.sample_indices(dim, k).into_iter().map(|i| i as u32).collect();
    idx.sort_unstable();
    let vals: Vec<f32> = (0..k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    SparseUpdate::single(SparseVec::new(dim, idx, vals))
}

fn main() {
    let mut b = Bench::new();
    let dim = 1 << 20;
    println!("# server aggregation at J={dim}: dense zero-fill+axpy vs sparse union merge");
    let mut gates: Vec<(String, f64, f64)> = Vec::new();
    for n in [4usize, 16] {
        for frac in [0.001f64, 0.01, 0.1] {
            let k = ((dim as f64 * frac) as usize).max(1);
            let mut rng = Rng::seed_from(0xA6_6000 + n as u64);
            let ups: Vec<SparseUpdate> =
                (0..n).map(|_| worker_update(dim, k, &mut rng)).collect();
            let omega = 1.0 / n as f32;
            let weighted: Vec<(f32, &SparseUpdate)> =
                ups.iter().map(|u| (omega, u)).collect();
            let label = format!("n={n}/kfrac={frac}");
            // dense reference: the PR 5 server loop (zero-fill J, then
            // densify every worker's update in id order)
            let mut dense = vec![0.0f32; dim];
            let td = b.run_throughput(&format!("aggregate/dense/{label}"), n * k, || {
                dense.iter_mut().for_each(|v| *v = 0.0);
                for (w, up) in &weighted {
                    up.axpy_into(*w, &mut dense);
                }
                black_box(dense[0]);
            });
            let mut out = SparseUpdate::empty();
            let ts =
                b.run_throughput(&format!("aggregate/sparse_merge/{label}"), n * k, || {
                    merge_updates(&weighted, &mut out);
                    black_box(out.nnz());
                });
            // bit-identity gate: identical per-index add order means
            // the merge must EQUAL the dense aggregate, not approximate it
            assert_eq!(out.to_dense(), dense, "sparse merge must be bit-identical ({label})");
            println!(
                "# {label}: dense {} vs sparse {} ({:.1}x)",
                regtopk::util::bench::fmt_time(td),
                regtopk::util::bench::fmt_time(ts),
                td / ts.max(1e-12)
            );
            if frac == 0.001 {
                gates.push((label, td, ts));
            }
        }
    }
    // perf gate: at the paper's 0.1% regime the O(k·n) merge must beat
    // the O(J) dense loop at every worker count
    for (label, td, ts) in &gates {
        assert!(
            ts < td,
            "sparse merge must win at 0.1% sparsity: {label} sparse {ts}s vs dense {td}s"
        );
    }
    let path = bench_json_path();
    b.write_json(Path::new(&path))
        .unwrap_or_else(|e| eprintln!("# could not write {path}: {e}"));
    println!("# wrote {} results to {path}", b.results().len());
}
