#!/usr/bin/env bash
# CI entry point — the SAME stages run locally and in GitHub Actions
# (.github/workflows/ci.yml calls this script, so "works on my machine
# but not in CI" cannot happen by construction).
#
#   scripts/ci.sh            # everything: lint + build + test + verify smoke
#   scripts/ci.sh lint       # cargo fmt --check + cargo clippy -D warnings
#   scripts/ci.sh verify     # build + test + verify.sh smoke (refreshes BENCH_*.json)
#
# Both stages are HARD gates: rustfmt drift, clippy warnings, test
# failures or a crashed smoke run all fail the pipeline.
set -euo pipefail
cd "$(dirname "$0")/.."

stage="${1:-all}"

run_lint() {
    echo "== ci/lint: cargo fmt --check =="
    cargo fmt --check || {
        echo "FAIL: rustfmt drift — run 'cargo fmt' and commit the result"
        exit 1
    }
    echo "== ci/lint: cargo clippy --all-targets -- -D warnings =="
    # --all-targets lints tests and benches too — new test code must
    # clear the same bar as the library
    cargo clippy --all-targets -- -D warnings
}

run_verify() {
    # verify.sh is the tier-1 gate: cargo build --release, cargo test
    # -q, the groupwise/heterogeneous/quantized CLI smoke runs and the
    # quick-budget bench smoke (which refreshes BENCH_*.json for the
    # workflow's artifact upload)
    scripts/verify.sh
}

case "$stage" in
    lint)   run_lint ;;
    verify) run_verify ;;
    all)    run_lint; run_verify ;;
    *)
        echo "usage: scripts/ci.sh [lint|verify|all]" >&2
        exit 2
        ;;
esac

echo "ci ($stage): OK"
