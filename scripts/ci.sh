#!/usr/bin/env bash
# CI entry point — the SAME stages run locally and in GitHub Actions
# (.github/workflows/ci.yml calls this script, so "works on my machine
# but not in CI" cannot happen by construction).
#
#   scripts/ci.sh            # everything: lint + analyze + build + test + verify smoke
#   scripts/ci.sh lint       # cargo fmt --check + cargo clippy -D warnings
#   scripts/ci.sh analyze    # repo-invariant analyzer (repro lint), zero findings
#   scripts/ci.sh verify     # build + test + verify.sh smoke (refreshes BENCH_*.json)
#
# All stages are HARD gates: rustfmt drift, clippy warnings, analyzer
# findings, test failures or a crashed smoke run all fail the pipeline.
set -euo pipefail
cd "$(dirname "$0")/.."

stage="${1:-all}"

run_lint() {
    echo "== ci/lint: cargo fmt --check =="
    cargo fmt --check || {
        echo "FAIL: rustfmt drift — run 'cargo fmt' and commit the result"
        exit 1
    }
    echo "== ci/lint: cargo clippy --all-targets -- -D warnings =="
    # --all-targets lints tests and benches too — new test code must
    # clear the same bar as the library.  The unsafe-hygiene lints are
    # promoted to hard errors on top of the default set: every unsafe
    # block needs a SAFETY comment (also enforced semantically by
    # `repro lint`), and pointer casts must be explicit about what
    # they change.
    cargo clippy --all-targets -- -D warnings \
        -D clippy::undocumented_unsafe_blocks \
        -D clippy::ptr_as_ptr \
        -D clippy::ptr_cast_constness \
        -D clippy::transmute_ptr_to_ptr
}

run_analyze() {
    # The repo-invariant analyzer (rust/src/analysis): SAFETY comments,
    # unsafe-module allowlist, no stray thread::spawn, one byte
    # accountant, sockets confined to comm/transport.rs, no wall-clock
    # in deterministic paths, full SparsifierKind test matrices.  Exit
    # 1 on any finding.
    echo "== ci/analyze: repro lint =="
    cargo build --release --bin repro
    target/release/repro lint
    echo "== ci/analyze: SCHEMA.lock is the canonical rendering =="
    # byte-for-byte: same tree -> same lockfile (tentpole acceptance
    # criterion; any drift means a format changed without the
    # SCHEMA.lock + docs/WIRE.md update)
    target/release/repro lint --schema | cmp - SCHEMA.lock || {
        echo "FAIL: SCHEMA.lock is stale — regenerate with 'repro lint --schema-write'"
        echo "      and document the change under a '## vN' heading in docs/WIRE.md"
        exit 1
    }
    # machine-readable findings (waived ones included) for the
    # workflow's lint.json artifact upload
    target/release/repro lint --json > lint.json
}

run_verify() {
    # verify.sh is the tier-1 gate: cargo build --release, cargo test
    # -q, the groupwise/heterogeneous/quantized CLI smoke runs, the
    # 2-worker loopback-TCP smoke (worker processes over framed
    # sockets must reproduce the in-process summary byte-for-byte) and
    # the quick-budget bench smoke (which refreshes BENCH_*.json for
    # the workflow's artifact upload)
    scripts/verify.sh
}

case "$stage" in
    lint)    run_lint ;;
    analyze) run_analyze ;;
    verify)  run_verify ;;
    all)     run_lint; run_analyze; run_verify ;;
    *)
        echo "usage: scripts/ci.sh [lint|analyze|verify|all]" >&2
        exit 2
        ;;
esac

echo "ci ($stage): OK"
