#!/usr/bin/env bash
# Tier-1 verification gate + style check + perf/groupwise/networked
# smoke runs.
#
#   scripts/verify.sh          # build + tests + quick bench/CLI smoke
#   scripts/verify.sh --full   # also run the benches at full budget
#
# The bench smoke uses a tiny per-target budget (BENCH_BUDGET_MS) so it
# finishes in seconds; it exists to catch perf-path regressions that
# compile but crash/hang, and to refresh BENCH_PR1.json/BENCH_PR2.json
# coarsely.  EXPERIMENTS.md records full-budget numbers.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== style: cargo fmt --check (hard gate) =="
if cargo fmt --version >/dev/null 2>&1; then
    # Hard gate (ROADMAP item, flipped in PR 3): drift fails verify.
    # If this trips on a tree that predates the flip, run `cargo fmt`
    # once, commit the result, and re-run.
    cargo fmt --check || {
        echo "FAIL: rustfmt drift — run 'cargo fmt' and commit the result"
        exit 1
    }
else
    echo "rustfmt unavailable on this host; skipping"
fi

echo "== groupwise smoke: repro train --groups/--budget =="
smoke_dir="$(mktemp -d)"
trap 'rm -rf "$smoke_dir"' EXIT
cat > "$smoke_dir/cfg.json" <<'EOF'
{"workers": 4, "iters": 25, "eta": 0.02,
 "sparsifier": {"name": "regtopk", "k": 10, "mu": 0.5, "q": 1.0}}
EOF
# the linreg testbed is J=100; 60+40 covers it, prop:0.1 -> k=[6,4]
target/release/repro train --config "$smoke_dir/cfg.json" \
    --groups conv:60,fc:40 --budget prop:0.1 --out "$smoke_dir/out"
# flat run from the same config must still work (equivalence net)
target/release/repro train --config "$smoke_dir/cfg.json" --out "$smoke_dir/out"

echo "== heterogeneous smoke: --policy + fig3 --layerwise =="
# heterogeneous policy table over named groups (ISSUE 3 tentpole)
target/release/repro train --config "$smoke_dir/cfg.json" \
    --groups conv:60,fc:40 --budget prop:0.1 \
    --policy 'conv*=regtopk:mu=0.3;*=topk' --out "$smoke_dir/out"
# fig3 layer-wise path: real artifacts when built, else the degraded
# linreg protocol — either way it must complete and print the
# per-group upload table
target/release/repro fig3 --layerwise --iters 8 --eval-every 0 \
    --policy '*.b=dense;*=regtopk:mu=0.5..0.1/8' --out "$smoke_dir/out"
# hetero sweep row sanity
target/release/repro sweep --param hetero --iters 40 --s 0.2

echo "== quantized smoke: bits policies + sweep --param bits =="
# mixed per-group bit widths with schedules + per-group eta (ISSUE 4
# tentpole); the per-group table must show the resolved bits column
target/release/repro train --config "$smoke_dir/cfg.json" \
    --groups conv:60,fc:40 --budget prop:0.1 \
    --policy 'conv*=regtopk:mu=0.3,bits=4;*=topk:bits=8..4/25,eta=1.5' \
    --out "$smoke_dir/out"
# accuracy-vs-wire-bytes sweep row (EXPERIMENTS.md §Quantization)
target/release/repro sweep --param bits --iters 40 --s 0.2

echo "== codec smoke: idx/levels policies + sweep --param codec =="
# the full wire stack on one run: entropy-coded indices, NUQ levels,
# and a residual-steered width (ISSUE 5 tentpole); the per-group table
# must show the idx column
target/release/repro train --config "$smoke_dir/cfg.json" \
    --groups conv:60,fc:40 --budget prop:0.1 \
    --policy 'conv*=regtopk:bits=4,idx=rice,levels=nuq;*=topk:bits=auto:4..8' \
    --out "$smoke_dir/out"
# codec matrix sweep (EXPERIMENTS.md §Compression) + the entropy-coded
# comm-table columns (measured rice bits vs the log J bound)
target/release/repro sweep --param codec --iters 40 --s 0.2
target/release/repro comm --s 0.01 --iters 5

echo "== downlink smoke: --downlink + sweep --param downlink =="
# sparse-domain aggregation + codec-compressed broadcast (ISSUE 6
# tentpole): lossless flat, then quantized downlink composed with a
# grouped quantized uplink; the run must print the downlink B/round
# line with the dense baseline next to it
target/release/repro train --config "$smoke_dir/cfg.json" \
    --downlink '*=' --out "$smoke_dir/out"
target/release/repro train --config "$smoke_dir/cfg.json" \
    --groups conv:60,fc:40 --budget prop:0.1 \
    --policy 'conv*=regtopk:bits=4;*=topk' \
    --downlink '*=:bits=8,idx=rice' --out "$smoke_dir/out"
# downlink codec matrix (EXPERIMENTS.md §Downlink protocol); s=0.05
# keeps the union support well under J so every sparse row must beat
# the dense broadcast
target/release/repro sweep --param downlink --iters 40 --s 0.05

echo "== half-width smoke: levels=fp16/bf16 uplink + downlink =="
# PR 10: true 16-bit wire values (RNE encode, exact widening decode);
# bare half rules need no bits= key and charge 16 bits/value
target/release/repro train --config "$smoke_dir/cfg.json" \
    --groups conv:60,fc:40 --budget prop:0.1 \
    --policy 'conv*=regtopk:levels=bf16;*=topk:levels=fp16,idx=rice' \
    --out "$smoke_dir/out"
target/release/repro train --config "$smoke_dir/cfg.json" \
    --downlink '*=:levels=fp16' --out "$smoke_dir/out"

echo "== networked smoke: 2-worker loopback TCP vs in-process =="
# PR 9 tentpole: the same run over real sockets — every worker a
# separate OS process speaking the framed wire protocol — must print a
# byte-identical summary line (final loss AND final gap), because the
# trajectory is bit-identical by construction.  The downlink variant
# additionally crosses SparseBroadcast frames and the per-direction
# byte totals on the downlink: line.
cat > "$smoke_dir/tcp.json" <<'EOF'
{"workers": 2, "iters": 20, "eta": 0.02,
 "sparsifier": {"name": "regtopk", "k": 10, "mu": 0.5, "q": 1.0}}
EOF
target/release/repro train --config "$smoke_dir/tcp.json" \
    --out "$smoke_dir/out" | grep -E '^(train|downlink):' > "$smoke_dir/inproc.txt"
target/release/repro train --config "$smoke_dir/tcp.json" --transport tcp \
    --out "$smoke_dir/out" | grep -E '^(train|downlink):' > "$smoke_dir/tcp.txt"
diff "$smoke_dir/inproc.txt" "$smoke_dir/tcp.txt" || {
    echo "FAIL: TCP worker-process run diverged from the in-process run"
    exit 1
}
target/release/repro train --config "$smoke_dir/tcp.json" \
    --downlink '*=:bits=8,idx=rice' \
    --out "$smoke_dir/out" | grep -E '^(train|downlink):' > "$smoke_dir/inproc.txt"
target/release/repro train --config "$smoke_dir/tcp.json" --transport tcp \
    --downlink '*=:bits=8,idx=rice' \
    --out "$smoke_dir/out" | grep -E '^(train|downlink):' > "$smoke_dir/tcp.txt"
diff "$smoke_dir/inproc.txt" "$smoke_dir/tcp.txt" || {
    echo "FAIL: TCP downlink-compressed run diverged from the in-process run"
    exit 1
}

if [[ "${1:-}" == "--full" ]]; then
    echo "== bench (full budget) =="
    cargo bench --bench topk_select
    cargo bench --bench sparsifiers
    BENCH_JSON=BENCH_PR2.json cargo bench --bench layerwise
    BENCH_JSON=BENCH_PR3.json cargo bench --bench heterogeneous
    BENCH_JSON=BENCH_PR4.json cargo bench --bench quantized
    BENCH_JSON=BENCH_PR5.json cargo bench --bench codec
    BENCH_JSON=BENCH_PR6.json cargo bench --bench aggregate
    BENCH_JSON=BENCH_PR10.json cargo bench --bench kernels
else
    echo "== bench smoke (quick budget) =="
    BENCH_BUDGET_MS=60 cargo bench --bench topk_select
    BENCH_BUDGET_MS=60 cargo bench --bench sparsifiers
    BENCH_BUDGET_MS=60 BENCH_JSON=BENCH_PR2.json cargo bench --bench layerwise
    BENCH_BUDGET_MS=60 BENCH_JSON=BENCH_PR3.json cargo bench --bench heterogeneous
    BENCH_BUDGET_MS=60 BENCH_JSON=BENCH_PR4.json cargo bench --bench quantized
    BENCH_BUDGET_MS=60 BENCH_JSON=BENCH_PR5.json cargo bench --bench codec
    BENCH_BUDGET_MS=60 BENCH_JSON=BENCH_PR6.json cargo bench --bench aggregate
    BENCH_BUDGET_MS=60 BENCH_JSON=BENCH_PR10.json cargo bench --bench kernels
fi

echo "verify: OK"
