#!/usr/bin/env bash
# Tier-1 verification gate + perf smoke run.
#
#   scripts/verify.sh          # build + tests + quick bench smoke
#   scripts/verify.sh --full   # also run the benches at full budget
#
# The bench smoke uses a tiny per-target budget (BENCH_BUDGET_MS) so it
# finishes in seconds; it exists to catch perf-path regressions that
# compile but crash/hang, and to refresh BENCH_PR1.json coarsely.
# EXPERIMENTS.md records full-budget numbers.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if [[ "${1:-}" == "--full" ]]; then
    echo "== bench (full budget) =="
    cargo bench --bench topk_select
    cargo bench --bench sparsifiers
else
    echo "== bench smoke (quick budget) =="
    BENCH_BUDGET_MS=60 cargo bench --bench topk_select
    BENCH_BUDGET_MS=60 cargo bench --bench sparsifiers
fi

echo "verify: OK"
